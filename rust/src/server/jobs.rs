//! Resumable jobs: the checkpointed epoch executor behind the async
//! `POST /v1/jobs` API (DESIGN.md §16).
//!
//! A job is one experiment run sliced into **epochs** of `epoch_steps`
//! timesteps. The server's worker pool runs exactly one epoch per queue
//! item and re-enqueues a continuation past the queue's admission cap but
//! *behind* admitted work ([`crate::server::pool::Bounded::push_unbounded`]),
//! so a long run never pins a worker: progress queries, pause/resume and
//! fresh `/v1/run` traffic interleave at epoch boundaries even on a
//! single-worker pool.
//!
//! **Determinism contract.** A job's result body is byte-identical to
//! `outcome_json(run_experiment(cfg))` on the same config. The executor
//! replicates `pde::scenario::run_sim`'s protocol exactly — one storage
//! quantization up front, then `Sim::advance` chunks with continuing
//! `step_base` — and the §8/§9 engine contracts make chunked advances
//! bit-identical to one fused advance. Checkpoints reuse the `Sim`
//! save/restore that powers the adaptive widen-retry, plus
//! [`crate::pde::Arith::snapshot`] to carry backend counters and the R2F2
//! split register across the boundary, so a crash-resumed job replays the
//! lost epoch from identical state and lands on identical bytes.
//!
//! Hostile input is rejected at **submit** time with the same
//! [`ExperimentConfig::from_json`] serving limits as `/v1/run` — a giant
//! grid is a `400` before any worker allocates. The store is bounded on
//! both sides: at most `cap` live (non-terminal) jobs — beyond that,
//! submit returns [`SubmitError::Full`] and the server answers `503` —
//! and at most `cap` finished ones, evicted oldest-completion-first (a
//! terminal job is immutable, so its completion is its last meaningful
//! use; completion order is LRU order).

use crate::analysis::Log2Histogram;
use crate::config::{json_escape, parse_json, ExperimentConfig, Json};
use crate::coordinator::Outcome;
use crate::metrics::Registry;
use crate::pde::{decomp, swe2d::QuantScope, Arith, Ctx, F64Arith, QuantMode, Sim};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

/// Crash-resume attempts before a job is marked failed.
pub const MAX_ATTEMPTS: u32 = 3;
/// Per-job event-log cap. Non-terminal events past the cap are counted and
/// dropped (the log keeps its cursor semantics — nothing is ever removed
/// from the front); the terminal event always lands so streams terminate.
pub const MAX_EVENTS: usize = 4096;

/// Job lifecycle states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, no epoch run yet.
    Queued,
    /// Epochs are executing (or a continuation is queued).
    Running,
    /// Parked at an epoch boundary; `resume` re-enqueues it.
    Paused,
    /// Finished; the result body is ready.
    Done,
    /// Exhausted its crash-resume budget.
    Failed,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Paused => "paused",
            JobState::Done => "done",
            JobState::Failed => "failed",
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed)
    }
}

/// Live run state parked between epochs: the sim mid-trajectory and the
/// arithmetic backend mid-count.
struct RunState {
    sim: Box<dyn Sim + Send>,
    be: Box<dyn Arith + Send>,
    muls: u64,
    steps_done: usize,
    epochs_done: usize,
    /// Has the one-time storage quantization run (epoch 0 of a fresh or
    /// restarted trajectory)?
    quanted: bool,
}

/// Epoch-boundary checkpoint: everything a worker needs to replay the next
/// epoch after the previous owner panicked. `be` is `None` only for a
/// backend without [`Arith::snapshot`] — resuming then restarts from step
/// 0, which is still deterministic, just not incremental.
struct Checkpoint {
    saved: Vec<Vec<f64>>,
    steps_done: usize,
    epochs_done: usize,
    muls: u64,
    be: Option<Box<dyn Arith + Send>>,
}

/// One submitted job.
pub struct Job {
    pub id: String,
    cfg: ExperimentConfig,
    pub state: JobState,
    steps_total: usize,
    epoch_steps: usize,
    pub steps_done: usize,
    pub epochs_done: usize,
    /// Crash-resume count (panics survived so far).
    pub attempts: u32,
    /// Test-only fault injection: panic when this epoch index starts.
    fault_at_epoch: Option<usize>,
    run: Option<RunState>,
    checkpoint: Option<Checkpoint>,
    /// Is a worker currently inside `run_epoch` for this job?
    in_flight: bool,
    events: Vec<String>,
    events_dropped: u64,
    /// Final body, byte-identical to `outcome_json(run_experiment(cfg))`.
    pub body: Option<String>,
    pub error: Option<String>,
}

impl Job {
    fn push_event(&mut self, line: String, terminal: bool) {
        if terminal || self.events.len() < MAX_EVENTS - 1 {
            self.events.push(line);
        } else {
            self.events_dropped += 1;
        }
    }

    /// Events from `cursor` on (the streaming route's incremental read).
    pub fn events_from(&self, cursor: usize) -> &[String] {
        &self.events[cursor.min(self.events.len())..]
    }

    pub fn events_len(&self) -> usize {
        self.events.len()
    }

    /// Progress/status record for `GET /v1/jobs/:id`.
    pub fn status_json(&self) -> String {
        let mut s = format!(
            "{{\"id\": \"{}\", \"state\": \"{}\", \"title\": \"{}\", \"app\": \"{}\", \
             \"backend\": \"{}\", \"steps\": {}, \"steps_done\": {}, \"epochs\": {}, \
             \"epoch_steps\": {}, \"attempts\": {}, \"events\": {}, \"events_dropped\": {}, \
             \"result_ready\": {}",
            json_escape(&self.id),
            self.state.as_str(),
            json_escape(&self.cfg.title),
            json_escape(&self.cfg.app),
            json_escape(&self.cfg.backend.name()),
            self.steps_total,
            self.steps_done,
            self.epochs_done,
            self.epoch_steps,
            self.attempts,
            self.events.len(),
            self.events_dropped,
            self.body.is_some()
        );
        if let Some(e) = &self.error {
            s.push_str(&format!(", \"error\": \"{}\"", json_escape(e)));
        }
        s.push('}');
        s
    }
}

/// Why a submit was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Malformed or over-limit config — the server answers `400`.
    Bad(String),
    /// Live-job capacity reached — the server answers `503`.
    Full,
}

struct StoreInner {
    jobs: BTreeMap<String, Arc<Mutex<Job>>>,
    /// Terminal jobs in completion order (completion is a terminal job's
    /// last state change, so this is LRU order for eviction).
    terminal: VecDeque<String>,
    next_id: u64,
}

/// Bounded job store: at most `cap` live jobs (submit rejects beyond) and
/// at most `cap` terminal ones (oldest-completion evicted).
pub struct JobStore {
    inner: Mutex<StoreInner>,
    cap: usize,
}

impl JobStore {
    pub fn new(cap: usize) -> JobStore {
        JobStore {
            inner: Mutex::new(StoreInner {
                jobs: BTreeMap::new(),
                terminal: VecDeque::new(),
                next_id: 0,
            }),
            cap: cap.max(1),
        }
    }

    /// Validate a `POST /v1/jobs` body and admit the job. The config goes
    /// through the exact `/v1/run` gauntlet ([`ExperimentConfig::from_json`]
    /// including `check_serving_limits`) *before* any state is allocated —
    /// an oversized grid must cost a `400`, never a worker allocation.
    ///
    /// Two job-only sections ride along (both ignored by the config
    /// parser's unknown-key leniency, so they never perturb the result):
    /// `{"job": {"epoch_steps": N}}` sizes the epochs, and
    /// `{"fault": {"panic_at_epoch": K}}` arms a one-shot injected worker
    /// panic for the crash-resume tests.
    pub fn submit(&self, body: &[u8]) -> Result<String, SubmitError> {
        let text =
            std::str::from_utf8(body).map_err(|_| SubmitError::Bad("body is not UTF-8".into()))?;
        let json = parse_json(text).map_err(|e| SubmitError::Bad(format!("bad JSON: {e}")))?;
        let cfg = ExperimentConfig::from_json(&json)
            .map_err(|e| SubmitError::Bad(format!("bad config: {e}")))?;
        let steps_total = app_steps(&cfg);
        let epoch_steps = match json.get("job").and_then(|j| j.get("epoch_steps")) {
            None => steps_total.div_ceil(8).max(1),
            Some(v) => match v.as_usize().filter(|&n| n >= 1) {
                Some(n) => n,
                None => {
                    return Err(SubmitError::Bad("job.epoch_steps must be at least 1".into()))
                }
            },
        };
        let fault_at_epoch =
            json.get("fault").and_then(|f| f.get("panic_at_epoch")).and_then(Json::as_usize);

        let mut inner = self.inner.lock().unwrap();
        let live = inner.jobs.len() - inner.terminal.len();
        if live >= self.cap {
            return Err(SubmitError::Full);
        }
        inner.next_id += 1;
        let id = format!("job-{}", inner.next_id);
        let mut job = Job {
            id: id.clone(),
            state: JobState::Queued,
            steps_total,
            epoch_steps,
            steps_done: 0,
            epochs_done: 0,
            attempts: 0,
            fault_at_epoch,
            run: None,
            checkpoint: None,
            in_flight: false,
            events: Vec::new(),
            events_dropped: 0,
            body: None,
            error: None,
            cfg,
        };
        job.push_event(
            format!(
                "{{\"event\": \"submitted\", \"job\": \"{}\", \"app\": \"{}\", \
                 \"steps\": {}, \"epoch_steps\": {}}}",
                json_escape(&id),
                json_escape(&job.cfg.app),
                steps_total,
                epoch_steps
            ),
            false,
        );
        inner.jobs.insert(id.clone(), Arc::new(Mutex::new(job)));
        Ok(id)
    }

    pub fn get(&self, id: &str) -> Option<Arc<Mutex<Job>>> {
        self.inner.lock().unwrap().jobs.get(id).cloned()
    }

    /// `(live, terminal)` job counts, for the `/metrics` gauges.
    pub fn counts(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.jobs.len() - inner.terminal.len(), inner.terminal.len())
    }

    /// Record that `id` reached a terminal state; evicts the
    /// oldest-completed job beyond the cap. Returns how many were evicted.
    fn mark_terminal(&self, id: &str) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner.terminal.push_back(id.to_string());
        let mut evicted = 0;
        while inner.terminal.len() > self.cap {
            if let Some(old) = inner.terminal.pop_front() {
                inner.jobs.remove(&old);
                evicted += 1;
            }
        }
        evicted
    }

    /// Park a job at its next epoch boundary. Idempotent on an already
    /// paused job; `Err` on a terminal one.
    pub fn pause(&self, id: &str) -> Result<(), String> {
        let job = self.get(id).ok_or_else(|| format!("no job {id}"))?;
        let mut j = job.lock().unwrap();
        match j.state {
            JobState::Queued | JobState::Running => {
                j.state = JobState::Paused;
                let line = format!(
                    "{{\"event\": \"paused\", \"steps_done\": {}}}",
                    j.steps_done
                );
                j.push_event(line, false);
                Ok(())
            }
            JobState::Paused => Ok(()),
            JobState::Done | JobState::Failed => {
                Err(format!("job {id} is {}", j.state.as_str()))
            }
        }
    }

    /// Un-park a paused job. `Ok(true)` means the caller must re-enqueue a
    /// continuation (no worker currently owns the job); `Ok(false)` means
    /// an in-flight epoch will re-enqueue it itself.
    pub fn resume(&self, id: &str) -> Result<bool, String> {
        let job = self.get(id).ok_or_else(|| format!("no job {id}"))?;
        let mut j = job.lock().unwrap();
        match j.state {
            JobState::Paused => {
                j.state =
                    if j.steps_done == 0 && j.run.is_none() && j.checkpoint.is_none() {
                        JobState::Queued
                    } else {
                        JobState::Running
                    };
                let line = format!(
                    "{{\"event\": \"resumed\", \"steps_done\": {}}}",
                    j.steps_done
                );
                j.push_event(line, false);
                Ok(!j.in_flight)
            }
            JobState::Queued | JobState::Running => Ok(false),
            JobState::Done | JobState::Failed => {
                Err(format!("job {id} is {}", j.state.as_str()))
            }
        }
    }
}

/// Total timesteps of the app the config selects.
fn app_steps(cfg: &ExperimentConfig) -> usize {
    match cfg.app.as_str() {
        "heat" => cfg.heat.steps,
        "swe" => cfg.swe.steps,
        "advection" => cfg.advection.steps,
        "wave" => cfg.wave.steps,
        other => panic!("unknown app {other}"),
    }
}

/// The app's `snapshot_every` (every `Sim::advance` chunk must see the
/// same value `run_sim` passes, or snapshot cadence would diverge).
fn app_snapshot_every(cfg: &ExperimentConfig) -> usize {
    match cfg.app.as_str() {
        "heat" => cfg.heat.snapshot_every,
        "swe" => cfg.swe.snapshot_every,
        "advection" => cfg.advection.snapshot_every,
        "wave" => cfg.wave.snapshot_every,
        other => panic!("unknown app {other}"),
    }
}

/// The `Ctx` mode `run_experiment` drives this app with (`swe` is always
/// flux-scoped MulOnly there; `Outcome.mode` still reports the config's).
fn effective_mode(cfg: &ExperimentConfig) -> QuantMode {
    if cfg.app == "swe" {
        QuantMode::MulOnly
    } else {
        cfg.mode
    }
}

/// The sharded sim exactly as `decomp::run_*` constructs it, so the
/// chunked trajectory matches the one-shot run byte for byte.
fn build_sim(cfg: &ExperimentConfig) -> Box<dyn Sim + Send> {
    let shards = cfg.shards.max(1);
    match cfg.app.as_str() {
        "heat" => Box::new(decomp::DecompHeat::new(&cfg.heat, shards)),
        "swe" => Box::new(decomp::DecompSwe::new(&cfg.swe, QuantScope::UxFluxOnly, shards)),
        "advection" => Box::new(decomp::DecompAdvection::new(&cfg.advection, shards)),
        "wave" => Box::new(decomp::DecompWave::new(&cfg.wave, shards)),
        other => panic!("unknown app {other}"),
    }
}

/// The f64 ground-truth field, via the same sharded entry points
/// `run_experiment` uses.
fn reference_field(cfg: &ExperimentConfig) -> Vec<f64> {
    let shards = cfg.shards.max(1);
    match cfg.app.as_str() {
        "heat" => decomp::run_heat(&cfg.heat, &mut F64Arith, QuantMode::MulOnly, shards).u,
        "swe" => {
            decomp::run_swe(
                &cfg.swe,
                &mut F64Arith,
                QuantScope::UxFluxOnly,
                QuantMode::MulOnly,
                shards,
            )
            .h
        }
        "advection" => {
            decomp::run_advection(&cfg.advection, &mut F64Arith, QuantMode::MulOnly, shards).u
        }
        "wave" => decomp::run_wave(&cfg.wave, &mut F64Arith, QuantMode::MulOnly, shards).u,
        other => panic!("unknown app {other}"),
    }
}

fn fresh_run(cfg: &ExperimentConfig) -> RunState {
    RunState {
        sim: build_sim(cfg),
        be: cfg.backend.build_send(),
        muls: 0,
        steps_done: 0,
        epochs_done: 0,
        quanted: false,
    }
}

/// What one `run_epoch` call tells the worker loop to do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EpochOutcome {
    /// Re-enqueue a continuation (`Bounded::push_unbounded`).
    Continue,
    /// The job reached a terminal state — no continuation.
    Terminal,
    /// Nothing to do (paused, already terminal, evicted, or owned by
    /// another worker) — no continuation.
    Idle,
}

/// Run exactly one epoch of `id` on the calling worker thread.
///
/// Structure: (1) under the job lock, claim the run state (or the recipe
/// to rebuild it from the checkpoint) so progress queries stay responsive
/// while the epoch computes; (2) compute outside the lock inside this
/// function's **own** `catch_unwind` — the pool's outer guard would save
/// the worker but lose the continuation; (3) write back, checkpoint, and
/// decide whether to continue.
pub fn run_epoch(store: &JobStore, id: &str, reg: &Registry) -> EpochOutcome {
    let Some(job) = store.get(id) else {
        return EpochOutcome::Idle; // evicted
    };

    enum Boot {
        Live(RunState),
        Checkpoint {
            saved: Vec<Vec<f64>>,
            steps_done: usize,
            epochs_done: usize,
            muls: u64,
            be: Option<Box<dyn Arith + Send>>,
        },
        Fresh,
    }

    // Phase 1: claim the job.
    let (cfg, boot, fault, epoch_steps) = {
        let mut j = job.lock().unwrap();
        match j.state {
            JobState::Paused | JobState::Done | JobState::Failed => return EpochOutcome::Idle,
            JobState::Queued => j.state = JobState::Running,
            JobState::Running => {}
        }
        if j.in_flight {
            // A duplicate continuation (pause/resume race); the owner will
            // re-enqueue when it finishes.
            return EpochOutcome::Idle;
        }
        j.in_flight = true;
        // Disarm the fault *before* running so the resumed attempt cannot
        // trip over it again.
        let fault = j.fault_at_epoch == Some(j.epochs_done);
        if fault {
            j.fault_at_epoch = None;
        }
        let boot = match j.run.take() {
            Some(r) => Boot::Live(r),
            None => match &j.checkpoint {
                Some(ck) => Boot::Checkpoint {
                    saved: ck.saved.clone(),
                    steps_done: ck.steps_done,
                    epochs_done: ck.epochs_done,
                    muls: ck.muls,
                    // Snapshot-of-checkpoint: the checkpoint stays intact
                    // for the *next* panic.
                    be: ck.be.as_ref().and_then(|b| b.snapshot()),
                },
                None => Boot::Fresh,
            },
        };
        (j.cfg.clone(), boot, fault, j.epoch_steps)
    };

    // Phase 2: compute, outside the job lock.
    struct EpochDone {
        run: RunState,
        chunk: usize,
        overflows: u64,
        underflows: u64,
        min_abs: f64,
        max_abs: f64,
        nonfinite: u64,
        finished: Option<String>, // the final outcome body
        rel_err: f64,
    }
    let computed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> EpochDone {
            let mut run = match boot {
                Boot::Live(r) => r,
                Boot::Fresh => fresh_run(&cfg),
                Boot::Checkpoint { saved, steps_done, epochs_done, muls, be } => match be {
                    Some(be) => {
                        let mut sim = build_sim(&cfg);
                        sim.restore(&saved);
                        RunState { sim, be, muls, steps_done, epochs_done, quanted: true }
                    }
                    // No backend snapshot: restart the trajectory from step
                    // 0 — deterministic, just not incremental.
                    None => fresh_run(&cfg),
                },
            };
            if fault {
                panic!("injected worker fault at epoch {} of {id}", run.epochs_done);
            }
            let steps_total = app_steps(&cfg);
            let snapshot_every = app_snapshot_every(&cfg);
            let chunk = epoch_steps.min(steps_total - run.steps_done);
            let mode = effective_mode(&cfg);
            let ev0 = run.be.range_events().unwrap_or_default();
            let mut snaps: Vec<(usize, Vec<f64>)> = Vec::new();
            let delta = {
                let mut ctx = Ctx::new(run.be.as_mut(), mode);
                if !run.quanted {
                    run.sim.quant_state(&mut ctx);
                    run.quanted = true;
                }
                run.sim.advance(
                    &mut ctx,
                    chunk,
                    run.steps_done,
                    snapshot_every,
                    &mut snaps,
                    true,
                );
                ctx.muls
            };
            run.muls += delta;
            run.steps_done += chunk;
            run.epochs_done += 1;

            // Per-epoch range telemetry: the same observables the adaptive
            // scheduler's EpochTelemetry carries.
            let ev1 = run.be.range_events().unwrap_or_default();
            let mut tele: Vec<f64> = Vec::new();
            run.sim.telemetry(&mut tele);
            let mut hist = Log2Histogram::new();
            for &v in &tele {
                hist.record(v);
            }
            let (min_abs, max_abs) = hist.nonzero_range().unwrap_or((0.0, 0.0));

            let mut finished = None;
            let mut rel_err = 0.0;
            if run.steps_done >= steps_total {
                // Final assembly, replicating `run_experiment` exactly:
                // field, f64 reference, rel_l2, counters — then the same
                // `outcome_json` serializer (wall is excluded from it).
                let field = run.sim.primary_field();
                let reference = reference_field(&cfg);
                rel_err = crate::pde::rel_l2(&field, &reference);
                let outcome = Outcome {
                    title: cfg.title.clone(),
                    app: cfg.app.clone(),
                    backend: cfg.backend.name(),
                    mode: cfg.mode,
                    rel_err_vs_f64: rel_err,
                    muls: run.muls,
                    adjustments: run
                        .be
                        .r2f2_stats()
                        .map(|s| (s.overflow_adjustments, s.redundancy_adjustments)),
                    range_events: run.be.range_events().map(|e| (e.overflows, e.underflows)),
                    wall: std::time::Duration::ZERO,
                    field,
                };
                finished = Some(super::outcome_json(&outcome));
            }
            EpochDone {
                chunk,
                overflows: ev1.overflows - ev0.overflows,
                underflows: ev1.underflows - ev0.underflows,
                min_abs,
                max_abs,
                nonfinite: hist.nonfinite,
                finished,
                rel_err,
                run,
            }
        },
    ));

    // Phase 3: write back.
    let mut j = job.lock().unwrap();
    j.in_flight = false;
    match computed {
        Err(_) => {
            reg.inc("serve.jobs.panics", 1);
            j.attempts += 1;
            if j.attempts >= MAX_ATTEMPTS {
                j.state = JobState::Failed;
                j.error = Some(format!(
                    "worker panicked {} times; crash-resume budget exhausted",
                    j.attempts
                ));
                let line = format!(
                    "{{\"event\": \"failed\", \"error\": \"{}\"}}",
                    json_escape(j.error.as_deref().unwrap_or(""))
                );
                j.push_event(line, true);
                j.run = None;
                j.checkpoint = None;
                drop(j);
                reg.inc("serve.jobs.failed", 1);
                reg.inc("serve.jobs.evicted", store.mark_terminal(id));
                EpochOutcome::Terminal
            } else {
                // Resume from the last checkpoint (or step 0): the live run
                // died with the panic, so roll progress back to it.
                let (from_step, epochs) = match &j.checkpoint {
                    Some(ck) => (ck.steps_done, ck.epochs_done),
                    None => (0, 0),
                };
                j.steps_done = from_step;
                j.epochs_done = epochs;
                let attempt = j.attempts;
                let line = format!(
                    "{{\"event\": \"crash_resumed\", \"attempt\": {attempt}, \
                     \"from_step\": {from_step}}}"
                );
                j.push_event(line, false);
                drop(j);
                reg.inc("serve.jobs.crash_resumes", 1);
                EpochOutcome::Continue
            }
        }
        Ok(done) => {
            reg.inc("serve.jobs.epochs", 1);
            let run = done.run;
            j.steps_done = run.steps_done;
            j.epochs_done = run.epochs_done;
            let line = format!(
                "{{\"event\": \"epoch\", \"epoch\": {}, \"steps_done\": {}, \"steps\": {}, \
                 \"chunk\": {}, \"muls\": {}, \"overflows\": {}, \"underflows\": {}, \
                 \"nonfinite\": {}, \"min_abs\": {}, \"max_abs\": {}}}",
                run.epochs_done - 1,
                run.steps_done,
                j.steps_total,
                done.chunk,
                run.muls,
                done.overflows,
                done.underflows,
                done.nonfinite,
                super::json_f64(done.min_abs),
                super::json_f64(done.max_abs)
            );
            j.push_event(line, false);
            match done.finished {
                Some(body) => {
                    let line = format!(
                        "{{\"event\": \"done\", \"rel_err_vs_f64\": {}, \"muls\": {}}}",
                        super::json_f64(done.rel_err),
                        run.muls
                    );
                    j.push_event(line, true);
                    j.body = Some(body);
                    j.state = JobState::Done;
                    j.run = None;
                    j.checkpoint = None;
                    drop(j);
                    // Same accounting run_experiment performs.
                    reg.inc("jobs.completed", 1);
                    reg.inc("jobs.muls", run.muls);
                    reg.inc("serve.jobs.completed", 1);
                    reg.inc("serve.jobs.evicted", store.mark_terminal(id));
                    EpochOutcome::Terminal
                }
                None => {
                    // Checkpoint the epoch boundary, then park the live run.
                    j.checkpoint = Some(Checkpoint {
                        saved: run.sim.save(),
                        steps_done: run.steps_done,
                        epochs_done: run.epochs_done,
                        muls: run.muls,
                        be: run.be.snapshot(),
                    });
                    j.run = Some(run);
                    if j.state == JobState::Paused {
                        // Parked mid-epoch: keep the state, drop the
                        // continuation; `resume` re-enqueues.
                        EpochOutcome::Idle
                    } else {
                        EpochOutcome::Continue
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_experiment;
    use crate::server::outcome_json;

    fn tiny_heat_body(extra: &str) -> String {
        format!(
            "{{\"title\": \"jobs-test\", \"app\": \"heat\", \"backend\": \"fixed:E5M10\", \
             \"heat\": {{\"n\": 33, \"steps\": 48, \"dt\": 2.4e-4}}{extra}}}"
        )
    }

    fn drive_to_terminal(store: &JobStore, id: &str, reg: &Registry) -> usize {
        let mut spins = 0;
        loop {
            match run_epoch(store, id, reg) {
                EpochOutcome::Terminal | EpochOutcome::Idle => return spins,
                EpochOutcome::Continue => spins += 1,
            }
            assert!(spins < 10_000, "job {id} never terminated");
        }
    }

    fn expected_body(body: &str) -> String {
        let cfg = ExperimentConfig::from_json(&parse_json(body).unwrap()).unwrap();
        outcome_json(&run_experiment(&cfg, &Registry::new()))
    }

    #[test]
    fn submit_validates_like_v1_run() {
        let store = JobStore::new(4);
        assert!(matches!(
            store.submit(&[0xff, 0xfe]),
            Err(SubmitError::Bad(e)) if e == "body is not UTF-8"
        ));
        assert!(matches!(
            store.submit(b"{nope"),
            Err(SubmitError::Bad(e)) if e.starts_with("bad JSON")
        ));
        // The serving limits fire at submit time — before any allocation.
        let huge = "{\"app\": \"heat\", \"heat\": {\"n\": 2000000000}}";
        assert!(matches!(
            store.submit(huge.as_bytes()),
            Err(SubmitError::Bad(e)) if e.contains("serving limit")
        ));
        assert!(matches!(
            store.submit(tiny_heat_body(", \"job\": {\"epoch_steps\": 0}").as_bytes()),
            Err(SubmitError::Bad(e)) if e.contains("epoch_steps")
        ));
    }

    #[test]
    fn ids_are_deterministic_and_capacity_binds() {
        let store = JobStore::new(2);
        let a = store.submit(tiny_heat_body("").as_bytes()).unwrap();
        let b = store.submit(tiny_heat_body("").as_bytes()).unwrap();
        assert_eq!(a, "job-1");
        assert_eq!(b, "job-2");
        assert_eq!(store.submit(tiny_heat_body("").as_bytes()), Err(SubmitError::Full));
        assert_eq!(store.counts(), (2, 0));
    }

    #[test]
    fn job_body_is_byte_identical_to_run_experiment() {
        let reg = Registry::new();
        for body in [
            tiny_heat_body(""),
            tiny_heat_body(", \"job\": {\"epoch_steps\": 7}"), // unaligned chunks
            "{\"app\": \"wave\", \"backend\": \"r2f2:<3,9,3>\", \
              \"wave\": {\"n\": 17, \"steps\": 30}}"
                .to_string(),
            "{\"app\": \"swe\", \"backend\": \"fixed:E5M10\", \"mode\": \"full\", \
              \"swe\": {\"steps\": 8}}"
                .to_string(),
            "{\"app\": \"advection\", \"backend\": \"f32\", \
              \"advection\": {\"n\": 64, \"steps\": 40}, \"shards\": 3}"
                .to_string(),
        ] {
            let store = JobStore::new(4);
            let id = store.submit(body.as_bytes()).unwrap();
            drive_to_terminal(&store, &id, &reg);
            let job = store.get(&id).unwrap();
            let j = job.lock().unwrap();
            assert_eq!(j.state, JobState::Done, "{body}");
            assert_eq!(j.body.as_deref().unwrap(), expected_body(&body), "{body}");
        }
    }

    #[test]
    fn injected_panic_is_resumed_from_the_checkpoint() {
        let reg = Registry::new();
        let body = tiny_heat_body(", \"job\": {\"epoch_steps\": 10}, \
                                    \"fault\": {\"panic_at_epoch\": 2}");
        let store = JobStore::new(4);
        let id = store.submit(body.as_bytes()).unwrap();
        drive_to_terminal(&store, &id, &reg);
        let job = store.get(&id).unwrap();
        let j = job.lock().unwrap();
        assert_eq!(j.state, JobState::Done);
        assert_eq!(j.attempts, 1, "exactly one crash survived");
        assert!(
            j.events.iter().any(|e| e.contains("\"crash_resumed\"")),
            "events: {:?}",
            j.events
        );
        // The replayed epoch lands on identical bytes.
        assert_eq!(j.body.as_deref().unwrap(), expected_body(&body));
        assert_eq!(reg.counter("serve.jobs.crash_resumes"), 1);
    }

    #[test]
    fn panic_before_any_checkpoint_restarts_from_step_zero() {
        let reg = Registry::new();
        let body = tiny_heat_body(", \"fault\": {\"panic_at_epoch\": 0}");
        let store = JobStore::new(4);
        let id = store.submit(body.as_bytes()).unwrap();
        drive_to_terminal(&store, &id, &reg);
        let j = store.get(&id).unwrap();
        let j = j.lock().unwrap();
        assert_eq!(j.state, JobState::Done);
        assert_eq!(j.body.as_deref().unwrap(), expected_body(&body));
    }

    #[test]
    fn repeated_panics_exhaust_the_budget() {
        // A fault re-armed from the test side every epoch: fail after
        // MAX_ATTEMPTS. (Disarm-before-panic means one submit-time fault
        // can only fire once, so re-arm manually.)
        let reg = Registry::new();
        let store = JobStore::new(4);
        let id = store.submit(tiny_heat_body("").as_bytes()).unwrap();
        let mut outcome = EpochOutcome::Continue;
        let mut spins = 0;
        while outcome == EpochOutcome::Continue {
            {
                let job = store.get(&id).unwrap();
                let mut j = job.lock().unwrap();
                let e = j.epochs_done;
                j.fault_at_epoch = Some(e);
            }
            outcome = run_epoch(&store, &id, &reg);
            spins += 1;
            assert!(spins < 100);
        }
        let j = store.get(&id).unwrap();
        let j = j.lock().unwrap();
        assert_eq!(j.state, JobState::Failed);
        assert_eq!(j.attempts, MAX_ATTEMPTS);
        assert!(j.events.iter().any(|e| e.contains("\"failed\"")));
    }

    #[test]
    fn pause_parks_and_resume_continues() {
        let reg = Registry::new();
        let store = JobStore::new(4);
        let id = store
            .submit(tiny_heat_body(", \"job\": {\"epoch_steps\": 10}").as_bytes())
            .unwrap();
        assert_eq!(run_epoch(&store, &id, &reg), EpochOutcome::Continue);
        store.pause(&id).unwrap();
        assert_eq!(run_epoch(&store, &id, &reg), EpochOutcome::Idle, "paused jobs don't run");
        let before = store.get(&id).unwrap().lock().unwrap().steps_done;
        assert_eq!(before, 10);
        assert!(store.resume(&id).unwrap(), "caller must re-enqueue");
        drive_to_terminal(&store, &id, &reg);
        let j = store.get(&id).unwrap();
        let j = j.lock().unwrap();
        assert_eq!(j.state, JobState::Done);
        assert_eq!(j.body.as_deref().unwrap(), expected_body(&tiny_heat_body("")));
        assert!(j.events.iter().any(|e| e.contains("\"paused\"")));
        assert!(j.events.iter().any(|e| e.contains("\"resumed\"")));
    }

    #[test]
    fn terminal_jobs_are_evicted_oldest_completion_first() {
        let reg = Registry::new();
        let store = JobStore::new(2);
        let mut ids = Vec::new();
        for _ in 0..3 {
            let id = store.submit(tiny_heat_body("").as_bytes()).unwrap();
            drive_to_terminal(&store, &id, &reg);
            ids.push(id);
        }
        // Cap 2: the first-completed job is gone, the last two remain.
        assert!(store.get(&ids[0]).is_none(), "oldest terminal evicted");
        assert!(store.get(&ids[1]).is_some());
        assert!(store.get(&ids[2]).is_some());
        assert_eq!(store.counts(), (0, 2));
        // Evicted jobs idle rather than panic if a stale continuation pops.
        assert_eq!(run_epoch(&store, &ids[0], &reg), EpochOutcome::Idle);
    }

    #[test]
    fn event_log_is_capped_but_always_terminates() {
        let store = JobStore::new(2);
        let id = store.submit(tiny_heat_body("").as_bytes()).unwrap();
        let job = store.get(&id).unwrap();
        let mut j = job.lock().unwrap();
        for i in 0..(2 * MAX_EVENTS) {
            j.push_event(format!("{{\"event\": \"spam\", \"i\": {i}}}"), false);
        }
        assert_eq!(j.events_len(), MAX_EVENTS - 1);
        assert!(j.events_dropped > 0);
        j.push_event("{\"event\": \"done\"}".into(), true);
        assert_eq!(j.events_len(), MAX_EVENTS, "the terminal event always lands");
        assert!(j.events_from(MAX_EVENTS - 1)[0].contains("done"));
    }

    #[test]
    fn status_json_reports_progress() {
        let reg = Registry::new();
        let store = JobStore::new(4);
        let id = store
            .submit(tiny_heat_body(", \"job\": {\"epoch_steps\": 10}").as_bytes())
            .unwrap();
        let s = store.get(&id).unwrap().lock().unwrap().status_json();
        assert!(s.contains("\"state\": \"queued\""), "{s}");
        assert!(s.contains("\"steps\": 48"), "{s}");
        run_epoch(&store, &id, &reg);
        let s = store.get(&id).unwrap().lock().unwrap().status_json();
        assert!(s.contains("\"state\": \"running\""), "{s}");
        assert!(s.contains("\"steps_done\": 10"), "{s}");
        assert!(s.contains("\"result_ready\": false"), "{s}");
        drive_to_terminal(&store, &id, &reg);
        let s = store.get(&id).unwrap().lock().unwrap().status_json();
        assert!(s.contains("\"state\": \"done\""), "{s}");
        assert!(s.contains("\"result_ready\": true"), "{s}");
        // The status record parses as JSON.
        assert!(parse_json(&s).is_ok(), "{s}");
    }
}
