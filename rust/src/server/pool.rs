//! Persistent worker pool: the long-lived complement to the scoped/batch
//! [`crate::coordinator::parallel_map`] (DESIGN.md §12).
//!
//! `parallel_map` is the right shape for a finite batch — spawn, drain an
//! atomic index, join. A server needs the opposite lifecycle: workers that
//! outlive any one job, a **bounded** queue that applies backpressure by
//! rejecting (the acceptor turns a rejection into `503`), and a graceful
//! shutdown that drains what was admitted and joins every thread. Both the
//! queue ([`Bounded`]) and the pool ([`WorkerPool`]) are std-only:
//! `Mutex` + `Condvar`, no async runtime.
//!
//! Observability: each worker owns a private [`Registry`] (low contention —
//! one lock per counter bump, never shared across workers on the hot path);
//! the server's `/metrics` rollup folds every worker registry together
//! with [`Registry::merge`] and publishes the live queue depth as a gauge.

use crate::metrics::Registry;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

struct State<T> {
    queue: VecDeque<T>,
    shutdown: bool,
}

/// A bounded MPMC queue. `try_push` never blocks (callers reject instead);
/// `pop` blocks until an item arrives or shutdown has drained the queue.
pub struct Bounded<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
    cap: usize,
}

impl<T> Bounded<T> {
    /// Queue with room for `cap` (≥ 1) pending items.
    pub fn new(cap: usize) -> Bounded<T> {
        Bounded {
            state: Mutex::new(State { queue: VecDeque::new(), shutdown: false }),
            ready: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Admit `item`, or hand it back if the queue is full or shut down.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.shutdown || s.queue.len() >= self.cap {
            return Err(item);
        }
        s.queue.push_back(item);
        self.ready.notify_one();
        Ok(())
    }

    /// Re-admit a continuation at the back of the queue, bypassing the
    /// capacity cap. For job-epoch continuations (`server::jobs`): each
    /// running job has at most one continuation in flight and the job store
    /// is itself bounded, so the bypass is bounded by `jobs_cap` — a
    /// continuation must never be *lost* to a full queue. Back-of-queue
    /// placement is equally deliberate: already-admitted connections are
    /// served between epochs, which is what keeps a long job queryable,
    /// pausable and streamable on a single-worker pool instead of
    /// monopolizing it until done. The delay per epoch is bounded by the
    /// queue cap (new connections beyond it are rejected, not queued).
    /// Fails only after shutdown.
    pub fn push_unbounded(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.shutdown {
            return Err(item);
        }
        s.queue.push_back(item);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until an item is available. Returns `None` once the queue has
    /// been shut down **and** every admitted item has been drained — so a
    /// graceful shutdown finishes the work it accepted.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.queue.pop_front() {
                return Some(item);
            }
            if s.shutdown {
                return None;
            }
            s = self.ready.wait(s).unwrap();
        }
    }

    /// Current queue depth.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stop admitting items and wake every blocked `pop`.
    pub fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.ready.notify_all();
    }
}

/// A fixed set of long-lived worker threads draining a [`Bounded`] queue.
pub struct WorkerPool<T: Send + 'static> {
    queue: Arc<Bounded<T>>,
    handles: Vec<JoinHandle<()>>,
    registries: Vec<Registry>,
}

impl<T: Send + 'static> WorkerPool<T> {
    /// Spawn `workers` (≥ 1) threads running `handler` over the queue. The
    /// handler receives the worker's private [`Registry`]; the pool itself
    /// records `serve.served` and times `serve.handle_ns` around each job.
    pub fn new<F>(queue: Arc<Bounded<T>>, workers: usize, handler: F) -> WorkerPool<T>
    where
        F: Fn(T, &Registry) + Send + Sync + 'static,
    {
        let registries: Vec<Registry> = (0..workers.max(1)).map(|_| Registry::new()).collect();
        Self::with_registries(queue, registries, handler)
    }

    /// [`WorkerPool::new`] with caller-provided per-worker registries (one
    /// worker per registry). The server uses this so the `/metrics` route
    /// can reach every worker's registry through its shared state.
    pub fn with_registries<F>(
        queue: Arc<Bounded<T>>,
        registries: Vec<Registry>,
        handler: F,
    ) -> WorkerPool<T>
    where
        F: Fn(T, &Registry) + Send + Sync + 'static,
    {
        assert!(!registries.is_empty(), "worker pool needs at least one registry");
        let handler = Arc::new(handler);
        let handles = registries
            .iter()
            .map(|reg| {
                let queue = queue.clone();
                let handler = handler.clone();
                let reg = reg.clone();
                std::thread::spawn(move || {
                    while let Some(job) = queue.pop() {
                        // A panicking handler must cost one job, not one
                        // worker: config validation should make this
                        // unreachable, but a dead worker is a permanent
                        // capacity loss on a long-lived server.
                        let caught = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                reg.time("serve.handle_ns", || (*handler)(job, &reg));
                            }),
                        );
                        if caught.is_err() {
                            reg.inc("serve.panics", 1);
                        }
                        reg.inc("serve.served", 1);
                    }
                })
            })
            .collect();
        WorkerPool { queue, handles, registries }
    }

    /// Admit a job, or hand it back when the queue is full (the caller
    /// decides how to reject — the server answers `503`).
    pub fn submit(&self, job: T) -> Result<(), T> {
        self.queue.try_push(job)
    }

    /// Live queue depth.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Jobs completed across all workers.
    pub fn served(&self) -> u64 {
        self.registries.iter().map(|r| r.counter("serve.served")).sum()
    }

    /// The per-worker registries. The single `/metrics` rollup lives in
    /// `server::mod` (`rollup`); it reaches these via the registry handles
    /// the server passed to [`WorkerPool::with_registries`], so there is
    /// exactly one merge implementation to keep honest.
    pub fn registries(&self) -> &[Registry] {
        &self.registries
    }

    /// Graceful shutdown: stop admissions, drain what was accepted, join
    /// every worker thread. Returning means no pool thread is left.
    pub fn shutdown(self) {
        self.queue.shutdown();
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    #[test]
    fn bounded_rejects_when_full_and_when_shut_down() {
        let q: Bounded<u32> = Bounded::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        q.shutdown();
        assert_eq!(q.try_push(4), Err(4));
        // Admitted items drain even after shutdown.
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn push_unbounded_bypasses_the_cap_but_waits_its_turn() {
        let q: Bounded<u32> = Bounded::new(1);
        assert!(q.try_push(1).is_ok());
        assert_eq!(q.try_push(2), Err(2), "cap still binds ordinary pushes");
        assert!(q.push_unbounded(3).is_ok(), "continuations bypass the cap");
        assert_eq!(q.pop(), Some(1), "admitted work is served before the continuation");
        assert_eq!(q.pop(), Some(3));
        q.shutdown();
        assert_eq!(q.push_unbounded(4), Err(4), "nothing re-enters after shutdown");
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let q: Bounded<u32> = Bounded::new(0);
        assert!(q.try_push(1).is_ok());
        assert_eq!(q.try_push(2), Err(2));
    }

    #[test]
    fn pop_blocks_until_an_item_arrives() {
        let q: Arc<Bounded<u32>> = Arc::new(Bounded::new(4));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        assert!(q.try_push(7).is_ok());
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn pool_processes_all_admitted_jobs_and_joins() {
        let done = Arc::new(AtomicU64::new(0));
        let queue: Arc<Bounded<u64>> = Arc::new(Bounded::new(64));
        let pool = {
            let done = done.clone();
            WorkerPool::new(queue.clone(), 4, move |job, reg| {
                done.fetch_add(job, Ordering::SeqCst);
                reg.inc("test.jobs", 1);
            })
        };
        let mut admitted = 0u64;
        for i in 1..=50u64 {
            if pool.submit(i).is_ok() {
                admitted += i;
            }
        }
        // Graceful: shutdown drains everything that was admitted.
        pool.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), admitted);
        assert_eq!(queue.pop(), None, "queue drained and shut down");
    }

    #[test]
    fn pool_counts_served_and_rolls_up_worker_registries() {
        let queue: Arc<Bounded<u32>> = Arc::new(Bounded::new(64));
        let pool = WorkerPool::new(queue.clone(), 3, move |job, reg| {
            reg.inc("test.sum", job as u64);
        });
        for i in 0..30u32 {
            pool.submit(i).unwrap();
        }
        // Wait for the queue to drain before snapshotting.
        while !queue.is_empty() {
            std::thread::sleep(Duration::from_millis(5));
        }
        while pool.served() < 30 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let snap = Registry::new();
        for r in pool.registries() {
            snap.merge(r);
        }
        assert_eq!(snap.counter("serve.served"), 30);
        assert_eq!(snap.counter("test.sum"), (0..30u64).sum::<u64>());
        assert!(snap.timer_summary("serve.handle_ns").is_some());
        assert_eq!(pool.queue_depth(), 0);
        pool.shutdown();
    }

    #[test]
    fn a_panicking_job_does_not_kill_the_worker() {
        let queue: Arc<Bounded<u32>> = Arc::new(Bounded::new(8));
        let pool = WorkerPool::new(queue.clone(), 1, move |job, _| {
            if job == 1 {
                panic!("boom");
            }
        });
        pool.submit(1).unwrap();
        pool.submit(2).unwrap();
        while pool.served() < 2 {
            std::thread::sleep(Duration::from_millis(5));
        }
        let snap = Registry::new();
        for r in pool.registries() {
            snap.merge(r);
        }
        assert_eq!(snap.counter("serve.panics"), 1);
        assert_eq!(snap.counter("serve.served"), 2, "the worker survived job 1");
        pool.shutdown();
    }

    #[test]
    fn one_slot_queue_rejects_under_load() {
        // A deliberately slow single worker with a 1-slot queue: while the
        // worker holds job A and the queue holds job B, every submit fails.
        let queue: Arc<Bounded<u32>> = Arc::new(Bounded::new(1));
        let pool = WorkerPool::new(queue.clone(), 1, move |_, _| {
            std::thread::sleep(Duration::from_millis(40));
        });
        pool.submit(0).unwrap();
        // Wait until the worker has dequeued job A, then fill the slot.
        while !queue.is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
        pool.submit(1).unwrap();
        let mut rejected = 0;
        for i in 2..6u32 {
            if pool.submit(i).is_err() {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 4, "queue full while the worker is busy");
        pool.shutdown();
    }
}
