//! Content-addressed result cache for the simulation service
//! (DESIGN.md §12).
//!
//! Why caching is *sound* here, not merely fast: every run the server
//! executes is deterministic and bit-reproducible by construction — the
//! §8/§9/§11 contracts pin the scalar ≡ carrier ≡ packed operation streams,
//! and `coordinator::job::run_experiment` takes no RNG, no wall clock and
//! no thread-count-dependent path. Two requests with the same
//! [`ExperimentConfig`] therefore have byte-identical responses, so a
//! cached response is indistinguishable from a fresh run.
//!
//! The address is a canonical serialization of the parsed config (not of
//! the request text — two JSON bodies that differ only in key order or
//! whitespace map to the same entry). The serialization is the `Debug`
//! derive of `ExperimentConfig`: it is deterministic within a process, and
//! because derives track the struct definition, a future config field can
//! never be silently dropped from the address (the classic stale-cache
//! bug a hand-rolled serializer invites). An FNV-1a/64 digest of that
//! string is the externally visible address (`x-r2f2-key`); internally the
//! full string is the map key, so hash collisions cannot alias entries.
//!
//! **Determinism guard**: in debug builds a sampled fraction of cache hits
//! re-runs the computation and asserts the recomputed response is
//! byte-identical to the cached one — the serving layer's analogue of the
//! engine bit-identity suites. `cargo test` exercises it on every hit-heavy
//! suite; release servers skip it.

use crate::config::ExperimentConfig;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Re-verify every `GUARD_SAMPLE`-th hit per entry in debug builds.
const GUARD_SAMPLE: u64 = 4;

/// Total bytes of cached response bodies across all entries. The entry
/// cap alone is not a memory bound — serving limits admit multi-MB
/// bodies, and an allocation failure aborts the process.
const MAX_TOTAL_BYTES: usize = 256 * 1024 * 1024;

/// Bodies above this are served but never cached (one giant response must
/// not evict the whole working set).
const MAX_ENTRY_BYTES: usize = 32 * 1024 * 1024;

/// Canonical serialization of a config — the content being addressed.
pub fn canonical_config(cfg: &ExperimentConfig) -> String {
    format!("{cfg:?}")
}

/// FNV-1a 64-bit digest (std has no stable, seedable, portable hasher).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// `(canonical serialization, 16-hex-digit content address)` of a config.
pub fn content_key(cfg: &ExperimentConfig) -> (String, String) {
    let canonical = canonical_config(cfg);
    let hex = format!("{:016x}", fnv1a64(canonical.as_bytes()));
    (canonical, hex)
}

/// Cache effectiveness counters (monotonic since construction).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Responses too large to cache (served uncached).
    pub uncacheable: u64,
    /// Determinism-guard re-runs performed (debug builds only).
    pub guard_checks: u64,
}

struct Entry {
    /// Shared so a hit hands out a pointer clone, never an O(body) copy
    /// under the cache lock.
    value: Arc<String>,
    last_used: u64,
    hits: u64,
}

struct Inner {
    map: HashMap<String, Entry>,
    /// Sum of `value` lengths across entries (the byte bound).
    total_bytes: usize,
    tick: u64,
    stats: CacheStats,
}

/// An LRU-bounded map from canonical config to cached response body,
/// bounded by entry count and by total body bytes (whichever bites
/// first); bodies above `MAX_ENTRY_BYTES` are served uncached.
pub struct ResultCache {
    inner: Mutex<Inner>,
    cap: usize,
    max_total_bytes: usize,
    max_entry_bytes: usize,
}

impl ResultCache {
    /// Cache holding at most `cap` (≥ 1) entries and `MAX_TOTAL_BYTES`
    /// (256 MB) of bodies, whichever bound bites first.
    pub fn new(cap: usize) -> ResultCache {
        Self::with_byte_caps(cap, MAX_TOTAL_BYTES, MAX_ENTRY_BYTES)
    }

    /// [`ResultCache::new`] with explicit byte bounds (exposed for tests
    /// and non-default deployments).
    pub fn with_byte_caps(
        cap: usize,
        max_total_bytes: usize,
        max_entry_bytes: usize,
    ) -> ResultCache {
        ResultCache {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                total_bytes: 0,
                tick: 0,
                stats: CacheStats::default(),
            }),
            cap: cap.max(1),
            max_total_bytes: max_total_bytes.max(1),
            max_entry_bytes: max_entry_bytes.max(1),
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.lock().unwrap().stats
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes of cached response bodies currently held.
    pub fn total_bytes(&self) -> usize {
        self.inner.lock().unwrap().total_bytes
    }

    /// Return the cached response for `canonical`, computing and inserting
    /// it on a miss. The boolean is `true` on a hit.
    ///
    /// `compute` runs **outside** the lock, so one slow simulation never
    /// serializes the other workers; if two workers race the same miss,
    /// both compute (bit-identical results by the determinism contract)
    /// and the first insert wins. On a sampled hit in debug builds the
    /// determinism guard re-runs `compute` and asserts byte-identity.
    /// Bodies above `MAX_ENTRY_BYTES` are served but not cached.
    pub fn get_or_insert_with<F: FnOnce() -> String>(
        &self,
        canonical: &str,
        compute: F,
    ) -> (Arc<String>, bool) {
        {
            let mut g = self.inner.lock().unwrap();
            g.tick += 1;
            let tick = g.tick;
            let found = g.map.get_mut(canonical).map(|e| {
                e.last_used = tick;
                e.hits += 1;
                (e.value.clone(), e.hits)
            });
            if let Some((value, hits)) = found {
                g.stats.hits += 1;
                let guard = cfg!(debug_assertions) && hits % GUARD_SAMPLE == 1;
                if guard {
                    g.stats.guard_checks += 1;
                }
                drop(g);
                if guard {
                    let recomputed = compute();
                    assert_eq!(
                        recomputed.as_str(),
                        value.as_str(),
                        "determinism guard: re-run of a cached config diverged \
                         (the bit-reproducibility contract is broken)"
                    );
                }
                return (value, true);
            }
        }

        let value = Arc::new(compute());
        let bytes = value.len();
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        g.stats.misses += 1;
        if bytes > self.max_entry_bytes {
            g.stats.uncacheable += 1;
            return (value, false);
        }
        if !g.map.contains_key(canonical) {
            // Evict LRU entries until both the entry and byte bounds hold.
            while !g.map.is_empty()
                && (g.map.len() >= self.cap || g.total_bytes + bytes > self.max_total_bytes)
            {
                let lru = g.map.iter().min_by_key(|(_, e)| e.last_used).map(|(k, _)| k.clone());
                match lru {
                    Some(k) => {
                        if let Some(e) = g.map.remove(&k) {
                            g.total_bytes -= e.value.len();
                        }
                        g.stats.evictions += 1;
                    }
                    None => break,
                }
            }
            g.total_bytes += bytes;
            g.map.insert(
                canonical.to_string(),
                Entry { value: value.clone(), last_used: tick, hits: 0 },
            );
        }
        (value, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn content_key_is_stable_and_field_sensitive() {
        let a = ExperimentConfig::default();
        let mut b = ExperimentConfig::default();
        assert_eq!(content_key(&a), content_key(&b));
        b.heat.steps += 1;
        assert_ne!(content_key(&a).0, content_key(&b).0);
        assert_eq!(content_key(&a).1.len(), 16);
    }

    #[test]
    fn fnv_matches_known_vectors() {
        // Standard FNV-1a/64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn hit_returns_cached_value_without_recompute() {
        let c = ResultCache::new(8);
        let calls = AtomicU64::new(0);
        let f = || {
            calls.fetch_add(1, Ordering::SeqCst);
            "value".to_string()
        };
        let (v, hit) = c.get_or_insert_with("k", f);
        assert_eq!((v.as_str(), hit), ("value", false));
        assert_eq!(calls.load(Ordering::SeqCst), 1);
        // Hit 1 may be guard-sampled (debug); hit 2 never is, so the call
        // count must not move across it in either profile.
        let (_, hit) = c.get_or_insert_with("k", || {
            calls.fetch_add(1, Ordering::SeqCst);
            "value".to_string()
        });
        assert!(hit);
        let before = calls.load(Ordering::SeqCst);
        let (v, hit) = c.get_or_insert_with("k", || {
            calls.fetch_add(1, Ordering::SeqCst);
            "value".to_string()
        });
        assert!(hit);
        assert_eq!(v.as_str(), "value");
        assert_eq!(calls.load(Ordering::SeqCst), before, "hit 2 is never guard-sampled");
        assert_eq!(c.stats().hits, 2);
        assert_eq!(c.stats().misses, 1);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn determinism_guard_samples_hits_and_catches_divergence() {
        let c = ResultCache::new(8);
        let (_, hit) = c.get_or_insert_with("k", || "v".to_string());
        assert!(!hit);
        // First hit is sampled: a deterministic compute passes...
        let (_, hit) = c.get_or_insert_with("k", || "v".to_string());
        assert!(hit);
        assert_eq!(c.stats().guard_checks, 1);
        // ...and a diverging compute on the next sampled hit panics.
        for _ in 0..GUARD_SAMPLE - 1 {
            let _ = c.get_or_insert_with("k", || "v".to_string());
        }
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            c.get_or_insert_with("k", || "DIVERGED".to_string())
        }));
        assert!(r.is_err(), "guard must catch a non-reproducible run");
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let c = ResultCache::new(2);
        let _ = c.get_or_insert_with("a", || "A".to_string());
        let _ = c.get_or_insert_with("b", || "B".to_string());
        // Touch `a` so `b` is the LRU entry.
        let _ = c.get_or_insert_with("a", || "A".to_string());
        let _ = c.get_or_insert_with("c", || "C".to_string());
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats().evictions, 1);
        // `a` survives (hit), `b` was evicted (recomputes).
        let (_, hit_a) = c.get_or_insert_with("a", || "A".to_string());
        assert!(hit_a);
        let (_, hit_b) = c.get_or_insert_with("b", || "B".to_string());
        assert!(!hit_b);
    }

    #[test]
    fn distinct_keys_do_not_alias() {
        let c = ResultCache::new(8);
        let (va, _) = c.get_or_insert_with("a", || "A".to_string());
        let (vb, _) = c.get_or_insert_with("b", || "B".to_string());
        assert_eq!((va.as_str(), vb.as_str()), ("A", "B"));
    }

    #[test]
    fn byte_bound_evicts_and_oversized_bodies_bypass_the_cache() {
        // 100-byte total bound, 40-byte per-entry bound, generous entry cap.
        let c = ResultCache::with_byte_caps(64, 100, 40);
        let body30 = "x".repeat(30);
        let (_, _) = c.get_or_insert_with("a", || body30.clone());
        let (_, _) = c.get_or_insert_with("b", || body30.clone());
        let (_, _) = c.get_or_insert_with("c", || body30.clone());
        assert_eq!(c.total_bytes(), 90);
        // A 4th 30-byte entry exceeds 100 total → the LRU entry goes.
        let (_, _) = c.get_or_insert_with("d", || body30.clone());
        assert_eq!(c.total_bytes(), 90);
        assert_eq!(c.len(), 3);
        assert_eq!(c.stats().evictions, 1);
        let (_, hit_a) = c.get_or_insert_with("a", || body30.clone());
        assert!(!hit_a, "a was the LRU entry and must have been evicted");

        // An oversized body is served but never stored.
        let big = "y".repeat(41);
        let (v, hit) = c.get_or_insert_with("huge", || big.clone());
        assert_eq!((v.len(), hit), (41, false));
        assert_eq!(c.stats().uncacheable, 1);
        let (_, hit) = c.get_or_insert_with("huge", || big.clone());
        assert!(!hit, "oversized bodies recompute every time");
    }
}
