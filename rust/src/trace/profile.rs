//! Precision profiler + recommendation engine (ROADMAP item 4, the
//! RAPTOR direction from PAPERS.md).
//!
//! A **pilot** runs each rung of a scenario's adaptive ladder at
//! [`ScenarioSize::Quick`] against the f64 reference, collecting per-rung
//! range telemetry (overflow/underflow events, rel-L2 error, modeled
//! datapath cost from `r2f2core::resource` via `fixed_cost_lut`) plus the
//! reference field's magnitude histogram. The resulting [`ProfilePlan`]
//! recommends the narrowest *clean* rung — no overflow events and a
//! finite error — as the adaptive scheduler's starting rung
//! (profile-guided adaptation, [`ProfilePlan::seeded_policy`]).
//!
//! Why seeding is safe: the adaptive contract (`pde::adaptive`,
//! DESIGN.md §10) already guarantees the committed trajectory bit-equals
//! the wide-format fixed run regardless of the starting rung — a wrong
//! seed only costs the aborted narrow attempt it would have made anyway.
//! A *right* seed skips cold-start probing entirely, so the modeled cost
//! is never higher than the cold start's and strictly lower whenever the
//! cold start pays for an aborted attempt (`rust/tests/trace_identity.rs`
//! holds both across the whole registry).
//!
//! Everything the pilot measures is deterministic (fixed-format Quick
//! runs, logical counters), so plans are bit-reproducible; the pilot's
//! only outputs are JSON under schema [`PLAN_SCHEMA`] and optional
//! `profile.rung` trace events.

use crate::analysis::field_histogram;
use crate::coordinator::pool::default_workers;
use crate::pde::adaptive::AdaptivePolicy;
use crate::pde::scenario::{fixed_run_cost, ScenarioSpec, SCENARIOS};
use crate::pde::{rel_l2, F64Arith, FixedArith, QuantMode, ScenarioSize};
use crate::softfloat::FpFormat;
use crate::trace::{json_f64, Clock, Collector, Value};

/// The profile-plan artifact schema (EXPERIMENTS.md E14).
pub const PLAN_SCHEMA: &str = "r2f2-profile-plan/1";

/// One ladder rung's pilot measurement.
#[derive(Debug, Clone)]
pub struct PlanRung {
    /// Index into the scenario's adaptive ladder (narrow → wide).
    pub rung: usize,
    pub format: FpFormat,
    /// rel-L2 of the rung's Quick run vs the f64 reference.
    pub rel_err: f64,
    pub overflows: u64,
    pub underflows: u64,
    pub muls: u64,
    /// Modeled LUT cost of running the whole pilot at this rung
    /// (`fixed_run_cost`, i.e. `r2f2core::resource` per-mul LUTs × muls).
    pub modeled_cost_lut: f64,
    /// No overflow events and a finite error — eligible as a seed.
    pub clean: bool,
}

/// A pilot's recommendation for one scenario.
#[derive(Debug, Clone)]
pub struct ProfilePlan {
    pub scenario: String,
    /// Quantization mode the pilot ran under.
    pub mode: QuantMode,
    /// Octaves occupied by the f64 reference field's magnitudes.
    pub occupied_octaves: usize,
    /// Octaves holding 90% of the reference field's mass.
    pub bulk90_octaves: usize,
    /// Every ladder rung's measurement, in ladder order.
    pub rungs: Vec<PlanRung>,
    /// Recommended starting rung: the narrowest clean rung, else the
    /// widest rung when nothing narrower survived the pilot.
    pub seed_rung: usize,
}

fn mode_name(mode: QuantMode) -> &'static str {
    match mode {
        QuantMode::MulOnly => "mul-only",
        QuantMode::Full => "full",
    }
}

impl ProfilePlan {
    /// The recommended rung's measurement.
    pub fn recommended(&self) -> &PlanRung {
        &self.rungs[self.seed_rung]
    }

    /// The scenario's default adaptive policy, re-seeded at the
    /// recommended rung. Every other knob (ladder, epoch length,
    /// thresholds) is untouched, so the committed trajectory still
    /// bit-equals the wide fixed run.
    pub fn seeded_policy(&self, spec: &ScenarioSpec) -> AdaptivePolicy {
        let mut policy = (spec.adaptive_policy)();
        policy.start_rung = self.seed_rung.min(policy.ladder.len().saturating_sub(1));
        policy
    }

    /// The plan as one JSON object under [`PLAN_SCHEMA`].
    pub fn to_json(&self) -> String {
        let rec = self.recommended();
        let mut out = format!(
            "{{\"schema\": \"{}\", \"generator\": \"r2f2 profile\", \"scenario\": \"{}\"",
            PLAN_SCHEMA, self.scenario
        );
        out.push_str(&format!(
            ", \"pilot\": {{\"size\": \"quick\", \"mode\": \"{}\", \"occupied_octaves\": {}, \"bulk90_octaves\": {}}}",
            mode_name(self.mode),
            self.occupied_octaves,
            self.bulk90_octaves
        ));
        out.push_str(", \"rungs\": [");
        for (i, r) in self.rungs.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!(
                "{{\"rung\": {}, \"format\": \"{}\", \"rel_err\": {}, \"overflows\": {}, \"underflows\": {}, \"muls\": {}, \"modeled_cost_lut\": {}, \"clean\": {}}}",
                r.rung,
                r.format,
                json_f64(r.rel_err),
                r.overflows,
                r.underflows,
                r.muls,
                json_f64(r.modeled_cost_lut),
                r.clean
            ));
        }
        out.push_str(&format!(
            "], \"recommendation\": {{\"seed_rung\": {}, \"format\": \"{}\", \"predicted_rel_err\": {}, \"modeled_cost_lut\": {}}}}}",
            self.seed_rung,
            rec.format,
            json_f64(rec.rel_err),
            json_f64(rec.modeled_cost_lut)
        ));
        out
    }
}

/// Wrap a batch of plans as one artifact document.
pub fn plans_json(plans: &[ProfilePlan]) -> String {
    let mut out = format!(
        "{{\"schema\": \"{}\", \"generator\": \"r2f2 profile\", \"plans\": [",
        PLAN_SCHEMA
    );
    for (i, p) in plans.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&p.to_json());
    }
    out.push_str("]}");
    out
}

/// Run the pilot for one scenario: f64 reference plus one fixed-format
/// Quick run per ladder rung, batched engine, [`QuantMode::MulOnly`].
/// When a collector is given, each rung emits a `profile.rung` event on
/// lane `profile/<scenario>` (logical clock: rung index as epoch, the
/// rung run's mul counter).
pub fn run_pilot(spec: &ScenarioSpec, trace: Option<&Collector>) -> ProfilePlan {
    let mode = QuantMode::MulOnly;
    let mut f64_be = F64Arith;
    let reference = (spec.run)(ScenarioSize::Quick, &mut f64_be, mode, true);
    let hist = field_histogram(&reference.field, default_workers());
    let ladder = (spec.adaptive_policy)().ladder;
    let lane = format!("profile/{}", spec.name);

    let mut rungs = Vec::with_capacity(ladder.len());
    for (i, fmt) in ladder.iter().enumerate() {
        let mut be = FixedArith::new(*fmt);
        let run = (spec.run)(ScenarioSize::Quick, &mut be, mode, true);
        let (overflows, underflows) = match run.range_events {
            Some(e) => (e.overflows, e.underflows),
            None => (0, 0),
        };
        let rel_err = rel_l2(&run.field, &reference.field);
        let clean = overflows == 0 && rel_err.is_finite();
        let rung = PlanRung {
            rung: i,
            format: *fmt,
            rel_err,
            overflows,
            underflows,
            muls: run.muls,
            modeled_cost_lut: fixed_run_cost(*fmt, &run),
            clean,
        };
        if let Some(c) = trace {
            c.record(
                &lane,
                "profile.rung",
                Clock { step: 0, epoch: i as u64, muls: run.muls },
                vec![
                    ("format".into(), Value::Str(rung.format.to_string())),
                    ("rel_err".into(), Value::F64(rung.rel_err)),
                    ("overflows".into(), Value::U64(rung.overflows)),
                    ("clean".into(), Value::Bool(rung.clean)),
                ],
            );
        }
        rungs.push(rung);
    }
    let seed_rung = rungs
        .iter()
        .position(|r| r.clean)
        .unwrap_or_else(|| ladder.len().saturating_sub(1));
    let plan = ProfilePlan {
        scenario: spec.name.to_string(),
        mode,
        occupied_octaves: hist.occupied_octaves(),
        bulk90_octaves: hist.bulk_octaves(0.9),
        rungs,
        seed_rung,
    };
    if let Some(c) = trace {
        let rec = plan.recommended();
        c.record(
            &lane,
            "profile.plan",
            Clock { step: 0, epoch: plan.seed_rung as u64, muls: 0 },
            vec![
                ("seed_rung".into(), Value::U64(plan.seed_rung as u64)),
                ("format".into(), Value::Str(rec.format.to_string())),
                ("predicted_rel_err".into(), Value::F64(rec.rel_err)),
                ("modeled_cost_lut".into(), Value::F64(rec.modeled_cost_lut)),
            ],
        );
    }
    plan
}

/// Pilot every registry scenario, in registry order.
pub fn run_all_pilots(trace: Option<&Collector>) -> Vec<ProfilePlan> {
    SCENARIOS.iter().map(|s| run_pilot(s, trace)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_json;

    #[test]
    fn every_scenario_pilot_recommends_the_wide_rung() {
        // At Quick size every registry scenario's initial state already
        // overflows its narrow rung on encode (amplitudes 300–500 vs
        // E4M3's max finite 240; SWE's 0.5·g·h² flux vs E5M10's 65504),
        // so the narrowest clean rung is the wide one — the pilot must
        // find exactly that, never a dirty rung and never rung 0.
        for spec in SCENARIOS {
            let plan = run_pilot(spec, None);
            assert_eq!(plan.rungs.len(), (spec.adaptive_policy)().ladder.len());
            assert!(plan.rungs[plan.seed_rung].clean, "{}: dirty seed", spec.name);
            assert_eq!(plan.seed_rung, 1, "{}: expected wide seed", spec.name);
            assert_eq!(plan.recommended().format, spec.wide_format, "{}", spec.name);
            assert!(!plan.rungs[0].clean, "{}: narrow rung should overflow", spec.name);
            assert!(plan.rungs[0].overflows > 0, "{}", spec.name);
        }
    }

    #[test]
    fn plan_json_parses_and_carries_the_schema() {
        let plan = run_pilot(&SCENARIOS[0], None);
        let doc = parse_json(&plan.to_json()).expect("plan JSON parses");
        assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), PLAN_SCHEMA);
        assert_eq!(
            doc.get("scenario").unwrap().as_str().unwrap(),
            SCENARIOS[0].name
        );
        let rungs = doc.get("rungs").unwrap().as_arr().unwrap();
        assert_eq!(rungs.len(), plan.rungs.len());
        let rec = doc.get("recommendation").unwrap();
        assert_eq!(
            rec.get("seed_rung").unwrap().as_usize().unwrap(),
            plan.seed_rung
        );

        let batch = parse_json(&plans_json(&[plan])).expect("batch parses");
        assert_eq!(batch.get("plans").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn seeded_policy_only_moves_the_start_rung() {
        let spec = &SCENARIOS[0];
        let plan = run_pilot(spec, None);
        let seeded = plan.seeded_policy(spec);
        let default = (spec.adaptive_policy)();
        assert_eq!(seeded.start_rung, plan.seed_rung);
        assert_eq!(seeded.ladder, default.ladder);
        assert_eq!(seeded.epoch_len, default.epoch_len);
        assert_eq!(
            seeded.widen_overflow_threshold,
            default.widen_overflow_threshold
        );
    }

    #[test]
    fn pilot_trace_events_land_on_the_profile_lane() {
        let c = Collector::new();
        let plan = run_pilot(&SCENARIOS[0], Some(&c));
        let snap = c.snapshot();
        assert_eq!(snap.len(), plan.rungs.len() + 1);
        assert!(snap.iter().all(|e| e.lane == format!("profile/{}", SCENARIOS[0].name)));
        assert_eq!(snap.last().unwrap().name, "profile.plan");
    }
}
