//! Deterministic structured tracing (DESIGN.md §17).
//!
//! Spans and events on the solver/serving paths are stamped with **logical
//! clocks** — step, epoch and multiplication counters the computation
//! already owns — never with wall time. Wall-clock durations may be
//! *attached* to an event (`wall_ns`, recorded only from sanctioned modules
//! behind reasoned `r2f2-audit` wall-clock markers), but they live outside
//! the event's content: [`Collector::content_ndjson`] projects them away,
//! and everything that remains is bit-reproducible and worker/shard-count
//! invariant by the same contracts that make results reproducible
//! (`rust/tests/trace_identity.rs`).
//!
//! Collection mirrors `metrics::Registry`: a [`Collector`] is a cloneable
//! handle onto a bounded ring (oldest events dropped, drops accounted), and
//! per-worker collectors [`Collector::merge`] order-invariantly — the
//! export is sorted by `(lane, seq, content)`, so the bytes cannot depend
//! on which collector an event landed in or in which order rings merged.
//!
//! Export is ndjson under schema `r2f2-trace/1`: one header line, then one
//! event per line (`r2f2 run --trace FILE`, `GET /v1/trace`).

pub mod profile;

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::config::json_mini::escape;
use crate::pde::adaptive::{AdaptiveArith, AdaptiveReport, Decision};
use crate::pde::{QuantMode, ScenarioRun, ScenarioSize};
use crate::pde::scenario::ScenarioSpec;

/// The trace artifact schema (EXPERIMENTS.md E14).
pub const SCHEMA: &str = "r2f2-trace/1";

/// Default ring capacity. Sized so a full Adaptive-size scenario trace
/// (one event per committed epoch plus the summary events) never drops.
pub const DEFAULT_CAP: usize = 16 * 1024;

/// Logical timestamp: the counters the traced computation already owns.
/// Sources stamp whichever components they track and leave the rest 0 —
/// no component ever derives from a clock read.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Clock {
    /// Solver timestep at the event.
    pub step: u64,
    /// Epoch / phase index.
    pub epoch: u64,
    /// Multiplications issued so far (0 where the source doesn't count).
    pub muls: u64,
}

impl Clock {
    /// The all-zero clock for events with no solver position (lifecycle
    /// markers, request spans).
    pub fn zero() -> Clock {
        Clock::default()
    }
}

/// A typed event field value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    U64(u64),
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    fn to_json(&self) -> String {
        match self {
            Value::U64(v) => format!("{v}"),
            Value::I64(v) => format!("{v}"),
            Value::F64(v) => json_f64(*v),
            Value::Bool(v) => format!("{v}"),
            Value::Str(s) => format!("\"{}\"", escape(s)),
        }
    }
}

/// Deterministic JSON rendering for floats: shortest round-trip form,
/// non-finite mapped to `null` (JSON has no Inf/NaN literals).
pub(crate) fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

/// One span/event record.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Logical stream the event belongs to (`scenario/heat1d`,
    /// `server/http`, `run/swe`, ...). Sequence numbers are per-lane.
    pub lane: String,
    /// Per-lane sequence number, assigned under the collector lock in
    /// emission order. Survives merges unchanged.
    pub seq: u64,
    /// Event name (`adaptive.epoch`, `http.request`, ...).
    pub name: String,
    pub clock: Clock,
    /// Typed payload in emission order (emitters are deterministic, so the
    /// order is too).
    pub fields: Vec<(String, Value)>,
    /// Sanctioned wall-clock attachment — **not** part of the event's
    /// deterministic content; see [`Collector::content_ndjson`].
    pub wall_ns: Option<u64>,
}

impl TraceEvent {
    /// The deterministic projection: everything except `wall_ns`.
    pub fn content_json(&self) -> String {
        self.render(false)
    }

    /// The full record, `wall_ns` included where present.
    pub fn to_json(&self) -> String {
        self.render(true)
    }

    fn render(&self, with_wall: bool) -> String {
        let mut out = format!(
            "{{\"lane\": \"{}\", \"seq\": {}, \"name\": \"{}\", \"step\": {}, \"epoch\": {}, \"muls\": {}",
            escape(&self.lane),
            self.seq,
            escape(&self.name),
            self.clock.step,
            self.clock.epoch,
            self.clock.muls
        );
        out.push_str(", \"fields\": {");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\": {}", escape(k), v.to_json()));
        }
        out.push('}');
        if with_wall {
            if let Some(w) = self.wall_ns {
                out.push_str(&format!(", \"wall_ns\": {w}"));
            }
        }
        out.push('}');
        out
    }
}

#[derive(Debug)]
struct Ring {
    cap: usize,
    events: VecDeque<TraceEvent>,
    dropped: u64,
    /// Next sequence number per lane. Monotonic even across drops, so
    /// merged exports sort stably and drops are visible as seq gaps.
    next_seq: BTreeMap<String, u64>,
}

/// A cloneable handle onto one bounded event ring (the `Registry` idiom:
/// clones share the ring; per-worker collectors merge order-invariantly).
#[derive(Debug, Clone)]
pub struct Collector {
    inner: Arc<Mutex<Ring>>,
}

impl Collector {
    pub fn new() -> Collector {
        Collector::with_capacity(DEFAULT_CAP)
    }

    pub fn with_capacity(cap: usize) -> Collector {
        let cap = cap.max(1);
        Collector {
            inner: Arc::new(Mutex::new(Ring {
                cap,
                events: VecDeque::new(),
                dropped: 0,
                next_seq: BTreeMap::new(),
            })),
        }
    }

    /// Record one event (no wall-clock attachment — the deterministic
    /// path). The per-lane sequence number is assigned here.
    pub fn record(&self, lane: &str, name: &str, clock: Clock, fields: Vec<(String, Value)>) {
        self.push(lane, name, clock, fields, None);
    }

    /// Record one event with a wall-clock duration attached. Callers sit
    /// in sanctioned modules behind reasoned wall-clock allow markers; the
    /// attachment never enters [`Collector::content_ndjson`].
    pub fn record_wall(
        &self,
        lane: &str,
        name: &str,
        clock: Clock,
        fields: Vec<(String, Value)>,
        wall_ns: u64,
    ) {
        self.push(lane, name, clock, fields, Some(wall_ns));
    }

    fn push(
        &self,
        lane: &str,
        name: &str,
        clock: Clock,
        fields: Vec<(String, Value)>,
        wall_ns: Option<u64>,
    ) {
        let mut ring = self.inner.lock().unwrap();
        let seq = ring.next_seq.entry(lane.to_string()).or_insert(0);
        let event = TraceEvent {
            lane: lane.to_string(),
            seq: *seq,
            name: name.to_string(),
            clock,
            fields,
            wall_ns,
        };
        *seq += 1;
        if ring.events.len() == ring.cap {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        ring.events.push_back(event);
    }

    /// Fold `other`'s events into this collector, `Registry::merge` style:
    /// events keep their lane/seq identity, drop counts add, and per-lane
    /// sequence allocation resumes past the highest seen — so merging is
    /// order-invariant up to the canonical export sort. Merging a
    /// collector with itself (same ring) is a no-op.
    pub fn merge(&self, other: &Collector) {
        if Arc::ptr_eq(&self.inner, &other.inner) {
            return;
        }
        let (theirs, their_dropped, their_seqs) = {
            let ring = other.inner.lock().unwrap();
            (
                ring.events.iter().cloned().collect::<Vec<_>>(),
                ring.dropped,
                ring.next_seq.clone(),
            )
        };
        let mut ring = self.inner.lock().unwrap();
        for (lane, next) in their_seqs {
            let slot = ring.next_seq.entry(lane).or_insert(0);
            *slot = (*slot).max(next);
        }
        ring.dropped += their_dropped;
        for event in theirs {
            if ring.events.len() == ring.cap {
                ring.events.pop_front();
                ring.dropped += 1;
            }
            ring.events.push_back(event);
        }
    }

    /// Events dropped to the ring bound (here and in merged-in rings).
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Events currently held.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all events (the window, not the per-lane seq counters — a
    /// cleared collector keeps allocating past what it already issued).
    pub fn clear(&self) {
        let mut ring = self.inner.lock().unwrap();
        ring.events.clear();
    }

    /// The held events in canonical export order: sorted by
    /// `(lane, seq, content)`. Insertion and merge order cannot show.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut events: Vec<TraceEvent> =
            self.inner.lock().unwrap().events.iter().cloned().collect();
        events.sort_by(|a, b| {
            (a.lane.as_str(), a.seq)
                .cmp(&(b.lane.as_str(), b.seq))
                .then_with(|| a.content_json().cmp(&b.content_json()))
        });
        events
    }

    /// Full ndjson export under [`SCHEMA`]: one header line, then one
    /// event per line in canonical order, `wall_ns` included where a
    /// sanctioned site attached it.
    pub fn to_ndjson(&self) -> String {
        self.export(true)
    }

    /// The deterministic projection of [`Collector::to_ndjson`]: identical
    /// bytes except that every `wall_ns` attachment is omitted. This is
    /// the artifact `trace_identity.rs` holds bit-identical across worker
    /// and shard counts.
    pub fn content_ndjson(&self) -> String {
        self.export(false)
    }

    fn export(&self, with_wall: bool) -> String {
        let events = self.snapshot();
        let dropped = self.dropped();
        let mut out = format!(
            "{{\"schema\": \"{}\", \"generator\": \"r2f2\", \"events\": {}, \"dropped\": {}}}\n",
            SCHEMA,
            events.len(),
            dropped
        );
        for e in &events {
            out.push_str(&if with_wall { e.to_json() } else { e.content_json() });
            out.push('\n');
        }
        out
    }
}

impl Default for Collector {
    fn default() -> Collector {
        Collector::new()
    }
}

/// Stable lowercase name for an adaptive decision.
pub fn decision_name(d: Decision) -> &'static str {
    match d {
        Decision::Stay => "stay",
        Decision::Widen => "widen",
        Decision::Narrow => "narrow",
    }
}

/// Run a registry scenario adaptively with tracing: installs the
/// [`AdaptiveArith::set_epoch_hook`] observer (one `adaptive.epoch` event
/// per epoch-boundary decision, retried attempts included), runs the
/// scenario through its registry hooks (sharded when `shards > 1`), then
/// appends the per-rung and run-summary events from the scheduler's
/// report.
///
/// Tracing cannot perturb the run: the hook observes decisions *after*
/// they are applied, on the driving thread, and the scheduler contract
/// (`pde::adaptive`) guarantees a hooked run is bit-identical to an
/// unhooked one. Event content is worker/shard invariant because the §13
/// decomp contract pins identical decisions and telemetry at any shard
/// count (`rust/tests/trace_identity.rs` asserts both).
pub fn trace_scenario_adaptive(
    spec: &ScenarioSpec,
    size: ScenarioSize,
    mode: QuantMode,
    batched: bool,
    shards: usize,
    collector: &Collector,
) -> (ScenarioRun, AdaptiveReport) {
    let lane = format!("scenario/{}", spec.name);
    let mut sched = AdaptiveArith::new((spec.adaptive_policy)());
    let sink = collector.clone();
    let hook_lane = lane.clone();
    sched.set_epoch_hook(move |e| {
        sink.record(
            &hook_lane,
            "adaptive.epoch",
            Clock { step: e.step as u64, epoch: e.epoch as u64, muls: 0 },
            vec![
                ("decision".into(), Value::Str(decision_name(e.decision).into())),
                ("format".into(), Value::Str(e.format.to_string())),
                ("overflows".into(), Value::U64(e.telemetry.events.overflows)),
                ("underflows".into(), Value::U64(e.telemetry.events.underflows)),
                ("nonfinite".into(), Value::U64(e.telemetry.nonfinite)),
                ("max_abs".into(), Value::F64(e.telemetry.max_abs)),
                ("min_abs".into(), Value::F64(e.telemetry.min_abs)),
                ("samples".into(), Value::U64(e.telemetry.samples)),
            ],
        );
    });
    let run = if shards > 1 {
        (spec.run_adaptive_sharded)(size, &mut sched, mode, batched, shards)
    } else {
        (spec.run_adaptive)(size, &mut sched, mode, batched)
    };
    let report = sched.report();
    for (i, (fmt, ops)) in report.ops_per_rung.iter().enumerate() {
        collector.record(
            &lane,
            "adaptive.rung",
            Clock { step: 0, epoch: i as u64, muls: *ops },
            vec![
                ("format".into(), Value::Str(fmt.to_string())),
                ("ops".into(), Value::U64(*ops)),
            ],
        );
    }
    collector.record(
        &lane,
        "scenario.done",
        Clock { step: 0, epoch: report.epochs as u64, muls: run.muls },
        vec![
            ("backend".into(), Value::Str(run.backend.clone())),
            ("decisions".into(), Value::U64(report.decisions.len() as u64)),
            ("widen_events".into(), Value::U64(report.widen_events)),
            ("narrow_events".into(), Value::U64(report.narrow_events)),
            ("final_format".into(), Value::Str(report.final_format.to_string())),
            ("modeled_cost_lut".into(), Value::F64(report.modeled_cost_lut)),
        ],
    );
    (run, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::parse_json;

    fn ev(c: &Collector, lane: &str, name: &str, step: u64) {
        c.record(
            lane,
            name,
            Clock { step, epoch: 0, muls: step * 10 },
            vec![("k".into(), Value::U64(step))],
        );
    }

    #[test]
    fn seq_is_per_lane_and_monotonic() {
        let c = Collector::new();
        ev(&c, "a", "x", 0);
        ev(&c, "b", "x", 0);
        ev(&c, "a", "x", 1);
        let snap = c.snapshot();
        let seqs: Vec<(String, u64)> =
            snap.iter().map(|e| (e.lane.clone(), e.seq)).collect();
        assert_eq!(
            seqs,
            vec![("a".to_string(), 0), ("a".to_string(), 1), ("b".to_string(), 0)]
        );
    }

    #[test]
    fn ring_bound_drops_oldest_with_accounting() {
        let c = Collector::with_capacity(3);
        for i in 0..5 {
            ev(&c, "a", "x", i);
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.dropped(), 2);
        let snap = c.snapshot();
        // Most recent kept; seqs keep counting through the drops.
        assert_eq!(snap.first().unwrap().seq, 2);
        assert_eq!(snap.last().unwrap().seq, 4);
        let header = c.to_ndjson();
        assert!(header.starts_with("{\"schema\": \"r2f2-trace/1\""));
        assert!(header.lines().next().unwrap().contains("\"dropped\": 2"));
    }

    #[test]
    fn merge_is_order_invariant_and_self_merge_is_noop() {
        let a = Collector::new();
        let b = Collector::new();
        ev(&a, "w0", "x", 0);
        ev(&a, "w0", "x", 1);
        ev(&b, "w1", "x", 0);
        ev(&b, "shared", "y", 7);

        let ab = Collector::new();
        ab.merge(&a);
        ab.merge(&b);
        let ba = Collector::new();
        ba.merge(&b);
        ba.merge(&a);
        assert_eq!(ab.to_ndjson(), ba.to_ndjson(), "merge order must not show");

        let before = a.to_ndjson();
        a.merge(&a.clone());
        assert_eq!(a.to_ndjson(), before, "self-merge is a no-op");
    }

    #[test]
    fn merge_resumes_lane_sequences_past_the_merged_high_water() {
        let a = Collector::new();
        let b = Collector::new();
        ev(&b, "lane", "x", 0);
        ev(&b, "lane", "x", 1);
        a.merge(&b);
        ev(&a, "lane", "x", 2);
        let seqs: Vec<u64> = a.snapshot().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2], "no seq collision after merge");
    }

    #[test]
    fn content_projection_strips_wall_and_nothing_else() {
        let c = Collector::new();
        c.record_wall("a", "x", Clock::zero(), vec![("k".into(), Value::Bool(true))], 1234);
        let full = c.to_ndjson();
        let content = c.content_ndjson();
        assert!(full.contains("\"wall_ns\": 1234"));
        assert!(!content.contains("wall_ns"));
        assert_eq!(full.replace(", \"wall_ns\": 1234", ""), content);
    }

    #[test]
    fn every_export_line_is_valid_json_even_with_hostile_names() {
        let c = Collector::new();
        c.record(
            "la\"ne\n",
            "ev\\il",
            Clock { step: 1, epoch: 2, muls: 3 },
            vec![
                ("we\"ird\tkey".into(), Value::Str("va\\lue\n".into())),
                ("nan".into(), Value::F64(f64::NAN)),
                ("neg".into(), Value::I64(-5)),
                ("f".into(), Value::F64(0.125)),
            ],
        );
        for line in c.to_ndjson().lines() {
            let doc = parse_json(line).expect("line parses");
            assert!(doc.get("schema").is_some() || doc.get("lane").is_some());
        }
        let snap = c.snapshot();
        let doc = parse_json(&snap[0].content_json()).unwrap();
        assert_eq!(doc.get("lane").unwrap().as_str().unwrap(), "la\"ne\n");
        assert_eq!(doc.get("step").unwrap().as_f64().unwrap(), 1.0);
        let fields = doc.get("fields").unwrap();
        assert_eq!(fields.get("we\"ird\tkey").unwrap().as_str().unwrap(), "va\\lue\n");
        assert_eq!(fields.get("nan"), Some(&crate::config::json_mini::Json::Null));
        assert_eq!(fields.get("f").unwrap().as_f64().unwrap(), 0.125);
    }

    #[test]
    fn clear_keeps_sequence_allocation() {
        let c = Collector::new();
        ev(&c, "a", "x", 0);
        c.clear();
        assert!(c.is_empty());
        ev(&c, "a", "x", 1);
        assert_eq!(c.snapshot()[0].seq, 1, "cleared collectors do not reissue seqs");
    }
}
