//! Property-based invariants over the numerics stack (proptest_mini).

use r2f2::pde::decomp::{partition, stencil_slab, Part};
use r2f2::proptest_mini::check;
use r2f2::r2f2core::{mul_packed, R2f2Config, R2f2Multiplier};
use r2f2::softfloat::{add, decode, encode, mul, FpFormat, Fp, Rounder};

fn arb_format(g: &mut r2f2::proptest_mini::Gen) -> FpFormat {
    FpFormat::new(g.int_in(2, 8) as u32, g.int_in(1, 14) as u32)
}

fn arb_config(g: &mut r2f2::proptest_mini::Gen) -> R2f2Config {
    *g.choose(&R2f2Config::TABLE1)
}

#[test]
fn prop_encode_decode_is_idempotent() {
    check("encode∘decode idempotent", 5000, |g| {
        let fmt = arb_format(g);
        let x = g.f64_nasty();
        let q = r2f2::softfloat::quantize(x, fmt);
        let qq = r2f2::softfloat::quantize(q, fmt);
        if q.to_bits() == qq.to_bits() {
            Ok(())
        } else {
            Err(format!("{fmt}: {x} → {q} → {qq}"))
        }
    });
}

#[test]
fn prop_decode_encode_roundtrips_representables() {
    check("decode∘encode identity on packed values", 5000, |g| {
        let fmt = arb_format(g);
        let v = Fp {
            sign: g.bool() as u8,
            exp: g.int_in(1, fmt.max_biased_exp()) as u32,
            frac: g.below(1 << fmt.m_w),
        };
        let x = decode(v, fmt);
        let (v2, flags) = encode(x, fmt, &mut Rounder::nearest_even());
        if v2 == v && flags.is_empty() {
            Ok(())
        } else {
            Err(format!("{fmt}: {v:?} → {x} → {v2:?} ({flags:?})"))
        }
    });
}

#[test]
fn prop_mul_commutative_and_sign_correct() {
    check("mul commutative + sign", 5000, |g| {
        let fmt = arb_format(g);
        let mut r = Rounder::nearest_even();
        let a = encode(g.f64_nasty(), fmt, &mut r).0;
        let b = encode(g.f64_nasty(), fmt, &mut r).0;
        let (ab, _) = mul(a, b, fmt, &mut r);
        let (ba, _) = mul(b, a, fmt, &mut r);
        if ab != ba {
            return Err(format!("{fmt}: not commutative {a:?} {b:?}"));
        }
        if ab.sign != a.sign ^ b.sign {
            return Err(format!("{fmt}: sign wrong {a:?} {b:?} -> {ab:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mul_magnitude_monotone_in_operand() {
    // |a| ≤ |a'| (same signs) ⇒ |a×b| ≤ |a'×b| after rounding/saturation.
    check("mul monotone", 3000, |g| {
        let fmt = arb_format(g);
        let mut r = Rounder::nearest_even();
        let b = encode(g.f64_log(1e-6, 1e6), fmt, &mut r).0;
        let x = g.f64_log(1e-6, 1e6);
        let y = x * g.f64_in(1.0, 16.0);
        let a1 = encode(x, fmt, &mut r).0;
        let a2 = encode(y, fmt, &mut r).0;
        let (p1, _) = mul(a1, b, fmt, &mut r);
        let (p2, _) = mul(a2, b, fmt, &mut r);
        if decode(p1, fmt).abs() <= decode(p2, fmt).abs() {
            Ok(())
        } else {
            Err(format!("{fmt}: {x}·b > {y}·b"))
        }
    });
}

#[test]
fn prop_add_commutative_and_bounded() {
    check("add commutative", 5000, |g| {
        let fmt = arb_format(g);
        let mut r = Rounder::nearest_even();
        let a = encode(g.f64_signed_log(1e-6, 1e6), fmt, &mut r).0;
        let b = encode(g.f64_signed_log(1e-6, 1e6), fmt, &mut r).0;
        let (s1, _) = add(a, b, fmt, &mut r);
        let (s2, _) = add(b, a, fmt, &mut r);
        if s1 != s2 {
            return Err(format!("{fmt}: {a:?}+{b:?}"));
        }
        // Result magnitude bounded by the format.
        if decode(s1, fmt).abs() > fmt.max_value() {
            return Err("exceeded max finite".into());
        }
        Ok(())
    });
}

#[test]
fn prop_truncated_mul_never_exceeds_exact() {
    // The flexible-partial-product truncation only clears low bits, so the
    // truncated product magnitude never exceeds the exact one.
    check("truncation conservative", 3000, |g| {
        let cfg = arb_config(g);
        let k = g.int_in(0, cfg.fx as i64) as u32;
        let fmt = cfg.format(k);
        let mut r = Rounder::nearest_even();
        let a = encode(g.f64_log(1e-4, 1e4), fmt, &mut r).0;
        let b = encode(g.f64_log(1e-4, 1e4), fmt, &mut r).0;
        let (apx, _) = mul_packed(a, b, cfg, k, &mut Rounder::nearest_even());
        let (exact, _) = mul(a, b, fmt, &mut Rounder::nearest_even());
        if decode(apx, fmt).abs() <= decode(exact, fmt).abs() {
            Ok(())
        } else {
            Err(format!("{cfg} k={k}: {a:?}×{b:?}"))
        }
    });
}

#[test]
fn prop_adjustment_unit_invariants() {
    // Across random multiplication streams: k stays in [0, FX]; results are
    // finite; counters are consistent with the observed events.
    check("adjustment invariants", 300, |g| {
        let cfg = arb_config(g);
        let mut unit = R2f2Multiplier::new(cfg);
        let mut last_k = unit.split();
        for _ in 0..200 {
            let a = g.f64_signed_log(1e-9, 1e9);
            let b = g.f64_log(1e-9, 1e9);
            let v = unit.mul(a, b);
            let k = unit.split();
            if k > cfg.fx {
                return Err(format!("{cfg}: split {k} out of range"));
            }
            if !v.is_finite() {
                return Err(format!("{cfg}: non-finite result {v}"));
            }
            // Narrowing moves one step at a time.
            if k + 1 < last_k {
                return Err(format!("{cfg}: narrowed more than one step {last_k}→{k}"));
            }
            last_k = k;
        }
        let st = unit.stats();
        if st.muls != 200 {
            return Err("mul count wrong".into());
        }
        Ok(())
    });
}

#[test]
fn prop_widening_result_is_at_least_as_accurate() {
    // After a widen-and-retry, the result's relative error vs the exact
    // product must be no worse than the saturated/flushed fixed result.
    check("widen helps", 2000, |g| {
        let cfg = R2f2Config::C16_393;
        let a = g.f64_log(1e2, 1e4);
        let b = g.f64_log(1e2, 1e4); // products 1e4..1e8 often overflow E5M10
        let exact = a * b;
        let mut unit = R2f2Multiplier::new(cfg);
        let v = unit.mul(a, b);
        let (fixed, _) = r2f2::softfloat::mul_f(a, b, FpFormat::E5M10);
        let e_unit = ((v - exact) / exact).abs();
        let e_fixed = ((fixed - exact) / exact).abs();
        if e_unit <= e_fixed + 1e-3 {
            Ok(())
        } else {
            Err(format!("{a}×{b}: unit {e_unit} worse than fixed {e_fixed}"))
        }
    });
}

#[test]
fn prop_datapath_latency_constant_for_all_configs() {
    check("datapath latency", 500, |g| {
        let cfg = arb_config(g);
        let s = r2f2::r2f2core::datapath::r2f2_schedule(cfg);
        if s.latency == 12 && s.ii == 4 {
            Ok(())
        } else {
            Err(format!("{cfg}: {}/{}", s.latency, s.ii))
        }
    });
}

#[test]
fn prop_quantize_is_nearest() {
    // |quantize(x) − x| ≤ |v − x| for the two neighbouring representables.
    check("quantize nearest", 3000, |g| {
        // e_w ≥ 3 so the normal range spans more than one octave.
        let fmt = FpFormat::new(g.int_in(3, 8) as u32, g.int_in(1, 14) as u32);
        let x = g.f64_log(fmt.min_normal() * 2.0, fmt.max_value() / 2.0);
        let q = r2f2::softfloat::quantize(x, fmt);
        // Step one ulp in each direction from q.
        let (fp, _) = encode(q, fmt, &mut Rounder::nearest_even());
        let up = Fp {
            frac: if fp.frac + 1 < (1 << fmt.m_w) { fp.frac + 1 } else { 0 },
            exp: if fp.frac + 1 < (1 << fmt.m_w) { fp.exp } else { fp.exp + 1 },
            ..fp
        };
        if up.exp <= fmt.max_biased_exp() as u32 {
            let vu = decode(up, fmt);
            if (vu - x).abs() < (q - x).abs() * (1.0 - 1e-12) {
                return Err(format!("{fmt}: {x} closer to {vu} than {q}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_partition_covers_grid_exactly_once() {
    // The decomposition contract (pde::decomp, DESIGN.md §13): for any
    // (n, shards) — including shards ≫ n — the parts are contiguous,
    // non-empty, cover [0, n) exactly once, and balance to within one.
    check("partition exact cover", 3000, |g| {
        let n = g.int_in(1, 5000) as usize;
        let shards = g.int_in(1, 600) as usize;
        let parts = partition(n, shards);
        if parts.len() != shards.min(n) {
            return Err(format!("n={n} shards={shards}: {} parts", parts.len()));
        }
        let mut lo = 0usize;
        for (i, p) in parts.iter().enumerate() {
            if p.lo != lo {
                return Err(format!("n={n} shards={shards}: part {i} starts at {}", p.lo));
            }
            if p.is_empty() {
                return Err(format!("n={n} shards={shards}: part {i} empty"));
            }
            lo = p.hi;
        }
        if lo != n {
            return Err(format!("n={n} shards={shards}: cover ends at {lo}"));
        }
        let sizes: Vec<usize> = parts.iter().map(Part::len).collect();
        let (min, max) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
        if max - min > 1 {
            return Err(format!("n={n} shards={shards}: sizes {min}..{max}"));
        }
        // shards > n degenerates to n single-element parts, never a panic.
        if shards > n && sizes.iter().any(|&s| s != 1) {
            return Err(format!("n={n} shards={shards}: oversharded sizes {sizes:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_stencil_slab_halos_overlap_by_exactly_one_node() {
    // Each shard's slab is its owned interior writes plus a one-node halo
    // on each side; every interior node is written by exactly one shard,
    // and each halo node is owned by the neighbouring shard (or is the
    // Dirichlet boundary).
    check("stencil slab halo overlap", 3000, |g| {
        let n = g.int_in(3, 4000) as usize;
        let shards = g.int_in(1, 64) as usize;
        check_slabs(n, shards)
    });
    // The smallest stencil grid: one interior node. Exactly one shard gets
    // a slab (covering all of [0, n)); boundary-only slivers get None.
    for shards in 1..=6 {
        check_slabs(3, shards).unwrap();
    }
}

fn check_slabs(n: usize, shards: usize) -> Result<(), String> {
    let parts = partition(n, shards);
    let mut writes = vec![0u32; n];
    for p in &parts {
        let Some((s0, s1)) = stencil_slab(*p, n) else {
            // A boundary-only sliver: no interior node to write.
            if !(p.hi <= 1 || p.lo >= n - 1 || p.is_empty()) {
                return Err(format!("n={n} shards={shards}: {p:?} wrongly slab-less"));
            }
            continue;
        };
        let (w0, w1) = (p.lo.max(1), p.hi.min(n - 1));
        if s0 != w0 - 1 || s1 != w1 + 1 {
            return Err(format!(
                "n={n} shards={shards}: {p:?} slab [{s0},{s1}) not writes [{w0},{w1}) ± 1"
            ));
        }
        if s1 > n {
            return Err(format!("n={n} shards={shards}: slab end {s1} out of grid"));
        }
        for w in writes.iter_mut().take(w1).skip(w0) {
            *w += 1;
        }
        // The halo nodes are *read* but owned elsewhere: the left halo is
        // the last cell of some earlier part (or node 0), symmetrically on
        // the right.
        if s0 >= p.lo && s0 != 0 {
            return Err(format!("n={n} shards={shards}: left halo {s0} not a neighbour's cell"));
        }
        if s1 - 1 < p.hi && s1 != n {
            return Err(format!("n={n} shards={shards}: right halo {} inside own part", s1 - 1));
        }
    }
    for (i, &w) in writes.iter().enumerate() {
        let want = u32::from(i >= 1 && i < n - 1);
        if w != want {
            return Err(format!("n={n} shards={shards}: node {i} written {w}× (want {want})"));
        }
    }
    Ok(())
}

#[test]
fn prop_stage_tracker_exact_stage_counts() {
    // The corrected StageTracker contract (the PR-3 `* 3` bugfix): for a
    // stream of exactly `expected_total` records split into `num_stages`,
    //   * expected_total ≥ num_stages → exactly num_stages stages, the
    //     first num_stages − 1 of floor(expected_total / num_stages)
    //     records each, the final stage absorbing the remainder;
    //   * 1 ≤ expected_total < num_stages → one stage per record;
    //   * expected_total = 0 → a single empty stage.
    check("stage tracker counts", 2000, |g| {
        let num_stages = g.int_in(1, 16) as usize;
        let expected_total = g.below(5000);
        let mut t = r2f2::analysis::StageTracker::new(num_stages, expected_total);
        for i in 0..expected_total {
            t.record(i as f64 + 1.0);
        }
        let stages = t.finish();
        if expected_total == 0 {
            if stages.len() != 1 || stages[0].count != 0 {
                return Err(format!("empty stream: {} stages", stages.len()));
            }
            return Ok(());
        }
        let want_stages = num_stages.min(expected_total as usize);
        if stages.len() != want_stages {
            return Err(format!(
                "total {expected_total} / {num_stages} stages: got {}",
                stages.len()
            ));
        }
        let per = (expected_total / num_stages as u64).max(1);
        let total: u64 = stages.iter().map(|s| s.count).sum();
        if total != expected_total {
            return Err(format!("records lost: {total} of {expected_total}"));
        }
        for (i, s) in stages.iter().enumerate() {
            if s.index != i {
                return Err(format!("stage {i} has index {}", s.index));
            }
            if i + 1 < stages.len() && s.count != per {
                return Err(format!("stage {i}: {} records, want {per}", s.count));
            }
        }
        // The final stage holds the remainder — never less than the others.
        let last = stages.last().unwrap().count;
        if expected_total >= num_stages as u64 && last < per {
            return Err(format!("final stage too small: {last} < {per}"));
        }
        Ok(())
    });
}

#[test]
fn prop_stage_tracker_exact_multiple_final_roll() {
    // When num_stages divides expected_total the final boundary lands on
    // the last record: record() must NOT roll an empty extra stage there —
    // finish() closes the final stage instead, and all stages are equal.
    check("stage tracker exact-multiple edge", 500, |g| {
        let num_stages = g.int_in(1, 12) as usize;
        let per = g.int_in(1, 40) as u64;
        let expected_total = per * num_stages as u64;
        let mut t = r2f2::analysis::StageTracker::new(num_stages, expected_total);
        for i in 0..expected_total {
            t.record((i + 1) as f64);
        }
        let stages = t.finish();
        if stages.len() != num_stages {
            return Err(format!("{num_stages} stages of {per}: got {}", stages.len()));
        }
        if !stages.iter().all(|s| s.count == per) {
            let counts: Vec<u64> = stages.iter().map(|s| s.count).collect();
            return Err(format!("unequal stages: {counts:?}"));
        }
        Ok(())
    });
}
