//! SWAR-tier conformance suite (DESIGN.md §14): the scalar packed word
//! kernels are the **specification**, the two-lane SWAR kernels are only an
//! implementation. Lane `k` of every `*_lanes` call must reproduce the
//! scalar kernel on that lane's operands — value bits and [`Flags`] —
//! exhaustively for E4M3 and across proptest regimes that hammer the
//! saturate/flush boundaries, plus the stochastic draw-order contract
//! (lane 0 draws before lane 1, i.e. flat element order).
//!
//! The solver half freezes the cache-tiled `stencil_multi` driver: tiled
//! execution at any worker count and any (non-divisible) tile split is
//! bit-identical to the untiled path and to the scalar specification, for
//! every registry scenario and engine — and composes with the decomp
//! sharding of §13. The CI `swar-identity` job runs this suite under
//! `R2F2_WORKERS` ∈ {1, 4} and greps the `MATRIX |` lines into the job
//! summary.

use r2f2::pde::heat1d::{self, HeatParams};
use r2f2::pde::init::HeatInit;
use r2f2::pde::scenario::{ScenarioRun, ScenarioSize, SCENARIOS};
use r2f2::pde::{BatchEngine, FixedArith, QuantMode};
use r2f2::proptest_mini::{check, Gen};
use r2f2::softfloat::{packed, swar, Flags, FpFormat, Rounder, RoundingMode};

// ---------------------------------------------------------------------------
// Kernel level: lane-for-lane vs the scalar word kernels
// ---------------------------------------------------------------------------

/// Every valid word of `fmt`: both signs, every fraction, every biased
/// exponent up to `max_biased_exp` (the all-ones exponent is reserved —
/// the kernels' precondition, same filter as the packed exhaustive suite).
fn valid_words(fmt: FpFormat) -> Vec<u32> {
    let e_mask = (1u32 << fmt.e_w) - 1;
    (0..(1u32 << fmt.total_bits()))
        .filter(|w| i64::from((w >> fmt.m_w) & e_mask) <= fmt.max_biased_exp())
        .collect()
}

const DET_MODES: [RoundingMode; 2] = [RoundingMode::NearestEven, RoundingMode::TowardZero];

/// Exhaustive E4M3 multiply: every (wa, wb) word pair, in **both** lane
/// positions, with cycling partner traffic in the other lane — both lanes
/// of every call are checked against the scalar kernel.
#[test]
fn exhaustive_e4m3_mul_lane_for_lane() {
    let fmt = FpFormat::new(4, 3);
    let pf = fmt.packed();
    let sf = fmt.swar();
    let words = valid_words(fmt);
    for mode in DET_MODES {
        let mut r = Rounder::new(mode, 0);
        for (i, &wa) in words.iter().enumerate() {
            let pa = words[(i * 7 + 3) % words.len()];
            for (j, &wb) in words.iter().enumerate() {
                let pb = words[(j * 13 + 5) % words.len()];
                let want = packed::mul_packed(wa, wb, &pf, &mut r);
                let partner = packed::mul_packed(pa, pb, &pf, &mut r);
                for lane in 0..2usize {
                    let (va, vb) = if lane == 0 {
                        (swar::pack2(wa, pa), swar::pack2(wb, pb))
                    } else {
                        (swar::pack2(pa, wa), swar::pack2(pb, wb))
                    };
                    let (v, fl) = swar::mul_packed_lanes(va, vb, &sf, &mut r);
                    let lanes = [swar::unpack2(v).0, swar::unpack2(v).1];
                    assert_eq!(
                        (lanes[lane], fl[lane]),
                        want,
                        "{mode:?}: {wa:#x} ⊗ {wb:#x} in lane {lane}"
                    );
                    assert_eq!(
                        (lanes[1 - lane], fl[1 - lane]),
                        partner,
                        "{mode:?}: partner {pa:#x} ⊗ {pb:#x} opposite lane {lane}"
                    );
                }
            }
        }
    }
}

/// Exhaustive E4M3 add: same matrix as the multiply — every word pair,
/// both lane positions, both deterministic modes.
#[test]
fn exhaustive_e4m3_add_lane_for_lane() {
    let fmt = FpFormat::new(4, 3);
    let pf = fmt.packed();
    let sf = fmt.swar();
    let words = valid_words(fmt);
    for mode in DET_MODES {
        let mut r = Rounder::new(mode, 0);
        for (i, &wa) in words.iter().enumerate() {
            let pa = words[(i * 11 + 1) % words.len()];
            for (j, &wb) in words.iter().enumerate() {
                let pb = words[(j * 17 + 9) % words.len()];
                let want = packed::add_packed(wa, wb, &pf, &mut r);
                let partner = packed::add_packed(pa, pb, &pf, &mut r);
                for lane in 0..2usize {
                    let (va, vb) = if lane == 0 {
                        (swar::pack2(wa, pa), swar::pack2(wb, pb))
                    } else {
                        (swar::pack2(pa, wa), swar::pack2(pb, wb))
                    };
                    let (v, fl) = swar::add_packed_lanes(va, vb, &sf, &mut r);
                    let lanes = [swar::unpack2(v).0, swar::unpack2(v).1];
                    assert_eq!(
                        (lanes[lane], fl[lane]),
                        want,
                        "{mode:?}: {wa:#x} + {wb:#x} in lane {lane}"
                    );
                    assert_eq!(
                        (lanes[1 - lane], fl[1 - lane]),
                        partner,
                        "{mode:?}: partner {pa:#x} + {pb:#x} opposite lane {lane}"
                    );
                }
            }
        }
    }
}

/// Proptest regime sweep: operands biased toward each format's saturate
/// and flush boundaries (plus zeros, specials, and raw nasties) through
/// the full encode → mul → add → decode lane pipeline, lane-for-lane
/// against the scalar kernels under both deterministic modes.
#[test]
fn lane_pipeline_matches_scalar_on_boundary_regimes() {
    for fmt in [FpFormat::E5M10, FpFormat::new(4, 3), FpFormat::E8M7, FpFormat::new(2, 1)] {
        let pf = fmt.packed();
        let sf = fmt.swar();
        let max = fmt.max_value();
        for mode in DET_MODES {
            let mut r = Rounder::new(mode, 0xB0B);
            check(&format!("swar-boundary-{fmt}-{mode:?}"), 4000, |g: &mut Gen| {
                let mut pick = |g: &mut Gen| match g.below(5) {
                    // Around the saturate boundary.
                    0 => g.f64_signed_log(max * 0.125, max * 8.0),
                    // Around the flush boundary (log-uniform far below 1).
                    1 => g.f64_signed_log(1e-14, 1e-2),
                    2 => 0.0,
                    3 => g.f64_signed_log(1e-3, 1e3),
                    _ => g.f64_nasty(),
                };
                let (a0, a1, b0, b1) = (pick(g), pick(g), pick(g), pick(g));

                // Scalar reference, flat element order.
                let (wa0, fa0) = packed::encode_bits(a0.to_bits(), &pf, &mut r);
                let (wa1, fa1) = packed::encode_bits(a1.to_bits(), &pf, &mut r);
                let (wb0, fb0) = packed::encode_bits(b0.to_bits(), &pf, &mut r);
                let (wb1, fb1) = packed::encode_bits(b1.to_bits(), &pf, &mut r);
                let (wp0, fp0) = packed::mul_packed(wa0, wb0, &pf, &mut r);
                let (wp1, fp1) = packed::mul_packed(wa1, wb1, &pf, &mut r);
                let (ws0, fs0) = packed::add_packed(wa0, wp0, &pf, &mut r);
                let (ws1, fs1) = packed::add_packed(wa1, wp1, &pf, &mut r);

                // SWAR pipeline on the same elements.
                let (va, fla) = swar::encode_lanes(a0, a1, &sf, &mut r);
                let (vb, flb) = swar::encode_lanes(b0, b1, &sf, &mut r);
                let (vp, flp) = swar::mul_packed_lanes(va, vb, &sf, &mut r);
                let (vs, fls) = swar::add_packed_lanes(va, vp, &sf, &mut r);

                let enc_ok = va == swar::pack2(wa0, wa1)
                    && vb == swar::pack2(wb0, wb1)
                    && fla == [fa0, fa1]
                    && flb == [fb0, fb1];
                let mul_ok = vp == swar::pack2(wp0, wp1) && flp == [fp0, fp1];
                let add_ok = vs == swar::pack2(ws0, ws1) && fls == [fs0, fs1];
                let dec_ok = {
                    let (d0, d1) = swar::decode_lanes(vs, &sf);
                    d0.to_bits() == packed::decode_word(ws0, &pf).to_bits()
                        && d1.to_bits() == packed::decode_word(ws1, &pf).to_bits()
                };
                if enc_ok && mul_ok && add_ok && dec_ok {
                    Ok(())
                } else {
                    Err(format!(
                        "a=({a0:e},{a1:e}) b=({b0:e},{b1:e}): enc={enc_ok} mul={mul_ok} \
                         add={add_ok} dec={dec_ok}"
                    ))
                }
            });
        }
    }
}

/// The draw-order contract: under stochastic rounding a `*_lanes` call
/// consumes the RNG exactly like the flat scalar loop — lane 0 first, then
/// lane 1. Two rounders seeded identically must stay in lock-step through
/// a long mixed stream (one desynchronized draw would cascade into every
/// later result, so bit-equality here pins the whole sequence).
#[test]
fn stochastic_draw_order_matches_flat_element_order() {
    for fmt in [FpFormat::E5M10, FpFormat::new(4, 3)] {
        let pf = fmt.packed();
        let sf = fmt.swar();
        let mut rs = Rounder::new(RoundingMode::Stochastic, 0xD1CE);
        let mut rk = Rounder::new(RoundingMode::Stochastic, 0xD1CE);
        check(&format!("swar-draw-order-{fmt}"), 3000, |g: &mut Gen| {
            let mut pick = |g: &mut Gen| match g.below(4) {
                0 => 0.0,
                _ => g.f64_signed_log(1e-9, 1e9),
            };
            let (a0, a1, b0, b1) = (pick(g), pick(g), pick(g), pick(g));

            let (wa0, fa0) = packed::encode_bits(a0.to_bits(), &pf, &mut rk);
            let (wa1, fa1) = packed::encode_bits(a1.to_bits(), &pf, &mut rk);
            let (wb0, fb0) = packed::encode_bits(b0.to_bits(), &pf, &mut rk);
            let (wb1, fb1) = packed::encode_bits(b1.to_bits(), &pf, &mut rk);
            let (wp0, fp0) = packed::mul_packed(wa0, wb0, &pf, &mut rk);
            let (wp1, fp1) = packed::mul_packed(wa1, wb1, &pf, &mut rk);
            let (wq0, fq0) = packed::add_packed(wp0, wb0, &pf, &mut rk);
            let (wq1, fq1) = packed::add_packed(wp1, wb1, &pf, &mut rk);

            let (va, fla) = swar::encode_lanes(a0, a1, &sf, &mut rs);
            let (vb, flb) = swar::encode_lanes(b0, b1, &sf, &mut rs);
            let (vp, flp) = swar::mul_packed_lanes(va, vb, &sf, &mut rs);
            let (vq, flq) = swar::add_packed_lanes(vp, vb, &sf, &mut rs);

            let ok = va == swar::pack2(wa0, wa1)
                && vb == swar::pack2(wb0, wb1)
                && vp == swar::pack2(wp0, wp1)
                && vq == swar::pack2(wq0, wq1)
                && fla == [fa0, fa1]
                && flb == [fb0, fb1]
                && flp == [fp0, fp1]
                && flq == [fq0, fq1];
            if ok {
                Ok(())
            } else {
                Err(format!("a=({a0:e},{a1:e}) b=({b0:e},{b1:e}): draw sequence diverged"))
            }
        });
    }
}

/// Flags are a union over the whole lane word, never smeared across lanes:
/// an overflowing lane 0 next to an in-range lane 1 must flag only lane 0
/// (and vice versa). Spot-checks the flag *independence* the exhaustive
/// tests imply.
#[test]
fn lane_flags_are_independent() {
    let fmt = FpFormat::E5M10;
    let pf = fmt.packed();
    let sf = fmt.swar();
    let mut r = Rounder::nearest_even();
    let (big, _) = packed::encode_bits(60000.0f64.to_bits(), &pf, &mut r);
    let (one, _) = packed::encode_bits(1.5f64.to_bits(), &pf, &mut r);
    let (tiny, _) = packed::encode_bits(1e-4f64.to_bits(), &pf, &mut r);

    let (_, fl) = swar::mul_packed_lanes(swar::pack2(big, one), swar::pack2(big, one), &sf, &mut r);
    assert!(fl[0].overflow() && !fl[1].overflow(), "overflow stays in lane 0: {fl:?}");
    let (_, fl) =
        swar::mul_packed_lanes(swar::pack2(one, tiny), swar::pack2(one, tiny), &sf, &mut r);
    assert!(!fl[0].underflow() && fl[1].underflow(), "underflow stays in lane 1: {fl:?}");
    let (_, fl) = swar::mul_packed_lanes(swar::pack2(one, one), swar::pack2(one, one), &sf, &mut r);
    assert_eq!(fl, [Flags::NONE, Flags::NONE], "clean lanes raise nothing");
}

// ---------------------------------------------------------------------------
// Solver level: cache-tiled stencil_multi vs untiled vs scalar spec
// ---------------------------------------------------------------------------

/// Tile geometries every identity case runs at: worker counts {1, 4}
/// (mirroring the CI `R2F2_WORKERS` axis), widths that split the interiors
/// non-divisibly (7 and 16 never divide the 99/63-node interiors), and a
/// width larger than any test grid (the untiled single-tile path).
const GEOMETRIES: [(usize, usize); 5] = [(1, 7), (1, 4096), (4, 7), (4, 16), (4, 4096)];

const TILED_ENGINES: [BatchEngine; 2] = [BatchEngine::Packed, BatchEngine::Swar];

fn engine_tag(e: BatchEngine) -> &'static str {
    match e {
        BatchEngine::Carrier => "carrier",
        BatchEngine::Packed => "packed",
        BatchEngine::Swar => "swar",
    }
}

fn assert_fields_bit_equal(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: node {i}: {} vs {}", a[i], b[i]);
    }
}

fn assert_runs_bit_equal(a: &ScenarioRun, b: &ScenarioRun, what: &str) {
    assert_fields_bit_equal(&a.field, &b.field, what);
    assert_eq!(a.muls, b.muls, "{what}: muls");
    assert_eq!(a.range_events, b.range_events, "{what}: range events");
    assert_eq!(a.r2f2_stats, b.r2f2_stats, "{what}: stats");
}

fn tiling_regimes() -> Vec<(&'static str, HeatParams)> {
    let base = HeatParams { n: 101, dt: 0.25 / (100.0f64 * 100.0), ..HeatParams::default() };
    vec![
        ("mid", HeatParams { steps: 120, snapshot_every: 40, ..base.clone() }),
        (
            "tiny",
            HeatParams {
                steps: 80,
                init: HeatInit::Sin { amplitude: 5e-4, cycles: 2.0 },
                ..base.clone()
            },
        ),
        (
            "huge",
            HeatParams { steps: 60, init: HeatInit::Sin { amplitude: 2.5e5, cycles: 2.0 }, ..base },
        ),
    ]
}

/// The load-bearing solver matrix: regime × engine × tile geometry, tiled
/// `Full`-mode multi-step runs bit-identical to the scalar specification
/// and the untiled path — fields, snapshots, mul counts, and range-event
/// counters (which also pins the per-tile event multiplicity partition).
#[test]
fn tiled_stencil_multi_bit_identical_to_untiled_and_scalar() {
    for (regime, p) in &tiling_regimes() {
        for engine in TILED_ENGINES {
            let mut scalar_be = FixedArith::new(FpFormat::E5M10).with_engine(engine);
            let want = heat1d::run_scalar(p, &mut scalar_be, QuantMode::Full);
            let mut untiled_be =
                FixedArith::new(FpFormat::E5M10).with_engine(engine).with_tiling(1, 1 << 20);
            let untiled = heat1d::run(p, &mut untiled_be, QuantMode::Full);
            for (workers, width) in GEOMETRIES {
                let what = format!("heat/{regime}/{}/tiles({workers}w,{width})", engine_tag(engine));
                let mut be = FixedArith::new(FpFormat::E5M10)
                    .with_engine(engine)
                    .with_tiling(workers, width);
                let got = heat1d::run(p, &mut be, QuantMode::Full);
                for (other, tag) in [(&want, "scalar"), (&untiled, "untiled")] {
                    assert_fields_bit_equal(&other.u, &got.u, &format!("{what} vs {tag}"));
                    assert_eq!(other.muls, got.muls, "{what} vs {tag}: muls");
                    assert_eq!(
                        other.range_events, got.range_events,
                        "{what} vs {tag}: range events (tile multiplicity partition)"
                    );
                    assert_eq!(
                        other.snapshots.len(),
                        got.snapshots.len(),
                        "{what} vs {tag}: snapshots"
                    );
                    for (i, ((ss, su), (gs, gu))) in
                        other.snapshots.iter().zip(got.snapshots.iter()).enumerate()
                    {
                        assert_eq!(ss, gs, "{what} vs {tag}: snapshot step {i}");
                        assert_fields_bit_equal(su, gu, &format!("{what} vs {tag}: snapshot {i}"));
                    }
                }
            }
            println!(
                "MATRIX | heat/{regime} | {} | tiles {:?} | bit-identical |",
                engine_tag(engine),
                GEOMETRIES
            );
        }
    }
}

/// Every registry scenario, both modes, Packed and Swar engines, tiled and
/// untiled, and composed with §13 decomp sharding: all bit-identical to
/// the default packed untiled run. The worker pool (`R2F2_WORKERS` in CI)
/// must not leak into any result.
#[test]
fn scenario_matrix_swar_and_tiled_bit_identical() {
    for spec in SCENARIOS {
        let fmt = spec.wide_format;
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            let mut base_be = FixedArith::new(fmt);
            let base = (spec.run)(ScenarioSize::Quick, &mut base_be, mode, true);
            for engine in TILED_ENGINES {
                for (workers, width) in [(1, 7), (4, 16)] {
                    let mut be =
                        FixedArith::new(fmt).with_engine(engine).with_tiling(workers, width);
                    let run = (spec.run)(ScenarioSize::Quick, &mut be, mode, true);
                    let what = format!(
                        "{}/{}/{mode:?}/tiles({workers}w,{width})",
                        spec.name,
                        engine_tag(engine)
                    );
                    assert_runs_bit_equal(&base, &run, &what);
                }
                // Tiling composes with decomp sharding: shards fan out over
                // the pool, each shard's slab tiles (and usually collapses
                // to one inline tile) — still bit-identical.
                let mut be = FixedArith::new(fmt).with_engine(engine).with_tiling(2, 9);
                let sharded = (spec.run_sharded)(ScenarioSize::Quick, &mut be, mode, true, 3);
                let what =
                    format!("{}/{}/{mode:?}/shards=3+tiles", spec.name, engine_tag(engine));
                assert_runs_bit_equal(&base, &sharded, &what);
            }
            println!(
                "MATRIX | {} | {mode:?} | packed+swar × tiled × sharded | bit-identical |",
                spec.name
            );
        }
    }
}
