//! The packed-domain engine contract (DESIGN.md §9): the carrier path is
//! the **specification**, the packed path is only an implementation. For
//! every kernel and every backend, packed execution must produce
//! **bit-identical values**, identical [`Flags`], identical R2F2 [`Stats`]
//! and identical fixed-format [`RangeEvents`] (with the scalar event
//! *multiplicity*) — across the backend × mode × regime matrix, the same
//! way `batched_vs_scalar.rs` froze §8.

use r2f2::pde::heat1d::{self, HeatParams};
use r2f2::pde::init::HeatInit;
use r2f2::pde::swe2d::{self, QuantScope, SweParams};
use r2f2::pde::{Arith, BatchEngine, FixedArith, QuantMode, R2f2Arith};
use r2f2::proptest_mini::{check, Gen};
use r2f2::r2f2core::R2f2Config;
use r2f2::softfloat::{
    add as carrier_add, decode, encode, mul as carrier_mul, packed, FpFormat, Rounder,
    RoundingMode,
};

// ---------------------------------------------------------------------------
// Kernel level: word kernels vs carrier kernels
// ---------------------------------------------------------------------------

fn kernel_formats() -> Vec<FpFormat> {
    vec![
        FpFormat::E5M10,
        FpFormat::new(4, 3),
        FpFormat::new(6, 9),
        FpFormat::E8M7,
        FpFormat::E8M23,
        FpFormat::new(2, 1),
    ]
}

fn rounder_pair(mode: RoundingMode, seed: u64) -> (Rounder, Rounder) {
    (Rounder::new(mode, seed), Rounder::new(mode, seed))
}

#[test]
fn encode_bits_matches_encode_on_log_uniform_regimes() {
    // Log-uniform magnitudes spanning far past every format's range, so
    // the saturate (OVERFLOW) and flush (UNDERFLOW) boundaries are hit
    // constantly — plus zeros, infinities, NaNs and raw bit patterns.
    for fmt in kernel_formats() {
        let pf = fmt.packed();
        for mode in [RoundingMode::NearestEven, RoundingMode::TowardZero, RoundingMode::Stochastic]
        {
            let (mut ra, mut rb) = rounder_pair(mode, 0xABC);
            check(&format!("encode-bits-{fmt}-{mode:?}"), 4000, |g: &mut Gen| {
                let x = g.f64_nasty();
                let (gw, gf) = packed::encode_bits(x.to_bits(), &pf, &mut ra);
                let (wfp, wf) = encode(x, fmt, &mut rb);
                if (pf.to_fp(gw), gf) == (wfp, wf) {
                    Ok(())
                } else {
                    Err(format!("x={x:e}: packed ({gw:#x}, {gf:?}) vs carrier ({wfp:?}, {wf:?})"))
                }
            });
        }
    }
}

#[test]
fn mul_packed_matches_carrier_on_log_uniform_regimes() {
    for fmt in kernel_formats() {
        let pf = fmt.packed();
        for mode in [RoundingMode::NearestEven, RoundingMode::TowardZero, RoundingMode::Stochastic]
        {
            let (mut ra, mut rb) = rounder_pair(mode, 0x3114);
            check(&format!("mul-packed-{fmt}-{mode:?}"), 4000, |g: &mut Gen| {
                // Operands spanning twelve decades either side of 1.0 drive
                // products across both range boundaries of every format.
                let a = if g.below(20) == 0 { 0.0 } else { g.f64_signed_log(1e-12, 1e12) };
                let b = g.f64_signed_log(1e-12, 1e12);
                let (wa, _) = encode(a, fmt, &mut Rounder::nearest_even());
                let (wb, _) = encode(b, fmt, &mut Rounder::nearest_even());
                let (gw, gf) = packed::mul_packed(pf.from_fp(wa), pf.from_fp(wb), &pf, &mut ra);
                let (wfp, wf) = carrier_mul(wa, wb, fmt, &mut rb);
                if (pf.to_fp(gw), gf) == (wfp, wf) {
                    Ok(())
                } else {
                    Err(format!("{a:e} × {b:e}: packed flags {gf:?} vs carrier {wf:?}"))
                }
            });
        }
    }
}

#[test]
fn add_packed_matches_carrier_on_log_uniform_regimes() {
    for fmt in kernel_formats() {
        let pf = fmt.packed();
        for mode in [RoundingMode::NearestEven, RoundingMode::TowardZero, RoundingMode::Stochastic]
        {
            let (mut ra, mut rb) = rounder_pair(mode, 0xADD);
            check(&format!("add-packed-{fmt}-{mode:?}"), 4000, |g: &mut Gen| {
                let a = if g.below(20) == 0 { 0.0 } else { g.f64_signed_log(1e-10, 1e10) };
                let b = if g.below(20) == 0 { -0.0 } else { g.f64_signed_log(1e-10, 1e10) };
                let (fa, _) = encode(a, fmt, &mut Rounder::nearest_even());
                let (fb, _) = encode(b, fmt, &mut Rounder::nearest_even());
                let (gw, gf) = packed::add_packed(pf.from_fp(fa), pf.from_fp(fb), &pf, &mut ra);
                let (wfp, wf) = carrier_add(fa, fb, fmt, &mut rb);
                if (pf.to_fp(gw), gf) == (wfp, wf) {
                    Ok(())
                } else {
                    Err(format!("{a:e} + {b:e}: packed flags {gf:?} vs carrier {wf:?}"))
                }
            });
        }
    }
}

#[test]
fn decode_word_matches_decode_on_random_codepoints() {
    for fmt in kernel_formats() {
        let pf = fmt.packed();
        check(&format!("decode-word-{fmt}"), 4000, |g: &mut Gen| {
            let exp = g.below(fmt.max_biased_exp() as u64 + 1) as u32;
            let frac = g.below(1 << fmt.m_w);
            let sign = g.bool() as u8;
            let fp = r2f2::softfloat::Fp { sign, exp, frac };
            let got = packed::decode_word(pf.from_fp(fp), &pf);
            let want = decode(fp, fmt);
            if got.to_bits() == want.to_bits() {
                Ok(())
            } else {
                Err(format!("{fp:?}: {got:e} vs {want:e}"))
            }
        });
    }
}

// ---------------------------------------------------------------------------
// Solver level: packed engine vs carrier engine vs scalar dispatch
// ---------------------------------------------------------------------------

/// The regimes of the §8 matrix: in-range, underflow-heavy, overflow-heavy.
fn heat_regimes() -> Vec<(&'static str, HeatParams)> {
    let base = HeatParams { n: 101, dt: 0.25 / (100.0f64 * 100.0), ..HeatParams::default() };
    vec![
        (
            "mid",
            HeatParams { steps: 300, snapshot_every: 100, ..base.clone() },
        ),
        (
            "tiny",
            HeatParams {
                steps: 200,
                init: HeatInit::Sin { amplitude: 5e-4, cycles: 2.0 },
                ..base.clone()
            },
        ),
        (
            "huge",
            HeatParams {
                steps: 100,
                init: HeatInit::Sin { amplitude: 2.5e5, cycles: 2.0 },
                ..base
            },
        ),
    ]
}

#[allow(clippy::type_complexity)]
fn engine_backends() -> Vec<(&'static str, Box<dyn Fn(BatchEngine) -> Box<dyn Arith>>)> {
    vec![
        (
            "fixed E5M10",
            Box::new(|e| Box::new(FixedArith::new(FpFormat::E5M10).with_engine(e)) as Box<dyn Arith>),
        ),
        (
            "fixed E6M9",
            Box::new(|e| {
                Box::new(FixedArith::new(FpFormat::new(6, 9)).with_engine(e)) as Box<dyn Arith>
            }),
        ),
        (
            "r2f2 <3,9,3>",
            Box::new(|e| {
                Box::new(R2f2Arith::new(R2f2Config::C16_393).with_engine(e)) as Box<dyn Arith>
            }),
        ),
        (
            "r2f2 <3,8,4>",
            Box::new(|e| {
                Box::new(R2f2Arith::new(R2f2Config::C16_384).with_engine(e)) as Box<dyn Arith>
            }),
        ),
    ]
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: lane {i}: {} vs {}",
            a[i],
            b[i]
        );
    }
}

#[test]
fn heat_packed_engine_bit_identical_across_modes_and_regimes() {
    for (regime, p) in &heat_regimes() {
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            for (name, mk) in &engine_backends() {
                let what = format!("heat/{regime}/{name}/{mode:?}");
                // The scalar path is the specification…
                let mut scalar_be = mk(BatchEngine::Packed);
                let s = heat1d::run_scalar(p, scalar_be.as_mut(), mode);
                // …the carrier engine is the frozen PR-1 implementation…
                let mut carrier_be = mk(BatchEngine::Carrier);
                let c = heat1d::run(p, carrier_be.as_mut(), mode);
                // …and the packed engine must match both, bit for bit.
                let mut packed_be = mk(BatchEngine::Packed);
                let b = heat1d::run(p, packed_be.as_mut(), mode);

                for (other, tag) in [(&s, "scalar"), (&c, "carrier")] {
                    assert_bits_eq(&other.u, &b.u, &format!("{what} vs {tag}"));
                    assert_eq!(other.muls, b.muls, "{what} vs {tag}: muls");
                    assert_eq!(other.r2f2_stats, b.r2f2_stats, "{what} vs {tag}: stats");
                    assert_eq!(
                        other.range_events, b.range_events,
                        "{what} vs {tag}: range events (multiplicity)"
                    );
                    assert_eq!(
                        other.snapshots.len(),
                        b.snapshots.len(),
                        "{what} vs {tag}: snapshots"
                    );
                    for (i, ((ss, su), (bs, bu))) in
                        other.snapshots.iter().zip(b.snapshots.iter()).enumerate()
                    {
                        assert_eq!(ss, bs, "{what} vs {tag}: snapshot step {i}");
                        assert_bits_eq(su, bu, &format!("{what} vs {tag}: snapshot {i}"));
                    }
                }
            }
        }
    }
}

#[test]
fn heat_regimes_actually_hit_the_boundaries() {
    // Guard the matrix itself: the tiny regime must underflow E5M10, the
    // huge regime must overflow it — otherwise the multiplicity checks
    // above are vacuous.
    let regimes = heat_regimes();
    let (_, tiny) = &regimes[1];
    let mut probe = FixedArith::new(FpFormat::E5M10);
    let ev = heat1d::run(tiny, &mut probe, QuantMode::MulOnly).range_events.unwrap();
    assert!(ev.underflows > 0, "tiny regime must underflow");
    let (_, huge) = &regimes[2];
    let mut probe = FixedArith::new(FpFormat::E5M10);
    let ev = heat1d::run(huge, &mut probe, QuantMode::MulOnly).range_events.unwrap();
    assert!(ev.overflows > 0, "huge regime must overflow");
}

#[test]
fn swe_packed_engine_bit_identical_both_scopes_and_modes() {
    let p = SweParams { steps: 25, ..SweParams::default() };
    for scope in [QuantScope::UxFluxOnly, QuantScope::AllFluxMuls] {
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            for (name, mk) in &engine_backends() {
                let what = format!("swe/{name}/{scope:?}/{mode:?}");
                let mut scalar_be = mk(BatchEngine::Packed);
                let s = swe2d::run_scalar_mode(&p, scalar_be.as_mut(), scope, mode);
                let mut carrier_be = mk(BatchEngine::Carrier);
                let c = swe2d::run_mode(&p, carrier_be.as_mut(), scope, mode);
                let mut packed_be = mk(BatchEngine::Packed);
                let b = swe2d::run_mode(&p, packed_be.as_mut(), scope, mode);

                for (other, tag) in [(&s, "scalar"), (&c, "carrier")] {
                    assert_bits_eq(&other.h, &b.h, &format!("{what} vs {tag}: h"));
                    assert_bits_eq(&other.u, &b.u, &format!("{what} vs {tag}: u"));
                    assert_bits_eq(&other.v, &b.v, &format!("{what} vs {tag}: v"));
                    assert_eq!(other.muls, b.muls, "{what} vs {tag}: muls");
                    assert_eq!(other.r2f2_stats, b.r2f2_stats, "{what} vs {tag}: stats");
                    assert_eq!(other.range_events, b.range_events, "{what} vs {tag}: events");
                    assert_eq!(
                        other.mass_drift.to_bits(),
                        b.mass_drift.to_bits(),
                        "{what} vs {tag}: mass drift"
                    );
                }
            }
        }
    }
}

#[test]
fn full_mode_packed_state_survives_long_runs() {
    // The tentpole property: a long Full-mode run through the packed
    // engine (state encoded once, stepped packed, decoded once) agrees
    // with the scalar specification to the last bit — including the
    // adjustment-free Dirichlet boundaries, which stay raw f64.
    let p = HeatParams {
        n: 101,
        dt: 0.25 / (100.0f64 * 100.0),
        steps: 1500,
        snapshot_every: 500,
        ..HeatParams::default()
    };
    let mut scalar_be = FixedArith::new(FpFormat::E5M10);
    let s = heat1d::run_scalar(&p, &mut scalar_be, QuantMode::Full);
    let mut packed_be = FixedArith::new(FpFormat::E5M10);
    let b = heat1d::run(&p, &mut packed_be, QuantMode::Full);
    assert_bits_eq(&s.u, &b.u, "long full-mode run");
    assert_eq!(s.range_events, b.range_events, "long full-mode events");
    assert_eq!(s.snapshots.len(), b.snapshots.len());
    for ((ss, su), (bs, bu)) in s.snapshots.iter().zip(b.snapshots.iter()) {
        assert_eq!(ss, bs);
        assert_bits_eq(su, bu, "long full-mode snapshot");
    }
}
