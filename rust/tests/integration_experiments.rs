//! Full-scale experiment integration: the paper's §5.3 numbers at the
//! paper's own workload sizes (1.5 M heat multiplications, 30 K SWE
//! multiplications), run natively through the coordinator.

use r2f2::config::{parse_backend, ExperimentConfig};
use r2f2::coordinator::Coordinator;
use r2f2::pde::{self, heat1d, swe2d, QuantMode};
use r2f2::r2f2core::R2f2Config;
use r2f2::softfloat::FpFormat;

#[test]
fn paper_scale_heat_run_adjustment_counts() {
    // §5.3: "During the entire computation that involves 1.5M
    // multiplications, R2F2 precision adjustment because of overflow
    // happened only 5 times ...; because of redundancy happened 23 times."
    // Same order of magnitude expected (exact counts depend on the solver's
    // initial data and sweep order, which the paper doesn't pin down).
    let p = heat1d::HeatParams::default();
    assert_eq!(p.expected_muls(), 1_497_000);
    let mut be = pde::R2f2Arith::new(R2f2Config::C16_393);
    let res = heat1d::run(&p, &mut be, QuantMode::MulOnly);
    let st = res.r2f2_stats.unwrap();
    assert_eq!(st.muls, 1_497_000);
    assert!(
        st.overflow_adjustments < 200,
        "overflow adjustments {} (paper: 5)",
        st.overflow_adjustments
    );
    assert!(
        st.redundancy_adjustments < 500,
        "redundancy adjustments {} (paper: 23)",
        st.redundancy_adjustments
    );
    assert_eq!(st.unresolved_range_events, 0);
}

#[test]
fn paper_scale_heat_r2f2_matches_f32() {
    // Fig 7(a)+(b): both 16-bit <3,9,3> and 15-bit <3,8,3> achieve "the
    // same simulation result" as single precision.
    let p = heat1d::HeatParams::default();
    let reference = heat1d::run(&p, &mut pde::F32Arith, QuantMode::MulOnly);
    for cfg in [R2f2Config::C16_393, R2f2Config::C15_383] {
        let mut be = pde::R2f2Arith::new(cfg);
        let res = heat1d::run(&p, &mut be, QuantMode::MulOnly);
        let err = pde::rel_l2(&res.u, &reference.u);
        assert!(err < 5e-3, "{cfg}: rel err {err}");
    }
}

#[test]
fn paper_scale_heat_full_half_fails() {
    // Fig 1(b): the all-half simulation is visibly wrong at paper scale.
    let p = heat1d::HeatParams::default();
    let reference = heat1d::run(&p, &mut pde::F64Arith, QuantMode::MulOnly);
    let mut half = pde::FixedArith::new(FpFormat::E5M10);
    let res = heat1d::run(&p, &mut half, QuantMode::Full);
    let err = pde::rel_l2(&res.u, &reference.u);
    // ~4% field error after only 1000 steps (0.1% of the diffusion time) is
    // a drastically wrong trajectory — an order of magnitude above every
    // mul-only backend at the same scale (R2F2 < 0.5%).
    assert!(err > 0.02, "full-half should fail: {err}");
    let mut r2 = pde::R2f2Arith::new(R2f2Config::C16_393);
    let ok = heat1d::run(&p, &mut r2, QuantMode::MulOnly);
    let err_r2 = pde::rel_l2(&ok.u, &reference.u);
    assert!(err > 10.0 * err_r2, "half {err} vs r2f2 {err_r2}");
}

#[test]
fn paper_scale_swe_run_30k_muls_and_counts() {
    // §5.3: "Within the 30K multiplications, R2F2 adjusted precision 7 and
    // 15 times, because of overflow and redundancy, respectively."
    let p = swe2d::SweParams::default();
    assert_eq!(p.expected_muls(), 30_720);
    let mut be = pde::R2f2Arith::new(R2f2Config::C16_384);
    let res = swe2d::run(&p, &mut be, swe2d::QuantScope::UxFluxOnly);
    let st = res.r2f2_stats.unwrap();
    assert_eq!(st.muls, 30_720);
    let total = st.overflow_adjustments + st.redundancy_adjustments;
    assert!(total >= 1 && total < 100, "adjustments {total} (paper: 7+15)");
}

#[test]
fn exp_init_heat_also_works() {
    // Fig 1(c)/(d): the exponential initialization spans (0, 2.2e4).
    use r2f2::pde::init::HeatInit;
    let mut p = heat1d::HeatParams::default();
    p.init = HeatInit::exp_default();
    p.steps = 500;
    let reference = heat1d::run(&p, &mut pde::F32Arith, QuantMode::MulOnly);
    let mut be = pde::R2f2Arith::new(R2f2Config::C16_393);
    let res = heat1d::run(&p, &mut be, QuantMode::MulOnly);
    let err = pde::rel_l2(&res.u, &reference.u);
    assert!(err < 5e-3, "exp init: {err}");
}

#[test]
fn coordinator_comparison_reproduces_figure_ordering() {
    // The compare command's invariant across both apps: err(f32) ≤
    // err(R2F2) < err(half-style baseline), with R2F2 close to f32.
    let coord = Coordinator::new(4);
    let mut configs = r2f2::coordinator::comparison_set("heat");
    // Full-half for the fixed baseline (the paper's Fig 1 semantics).
    for c in configs.iter_mut() {
        if c.backend.name().starts_with("fixed") {
            c.mode = QuantMode::Full;
        }
        c.heat.steps = 600;
        c.heat.n = 257;
        c.heat.dt = 0.25 / (256.0f64 * 256.0);
    }
    let outcomes = coord.run_batch(configs);
    let err_of = |name: &str| {
        outcomes
            .iter()
            .find(|o| o.backend.contains(name))
            .map(|o| o.rel_err_vs_f64)
            .unwrap()
    };
    assert!(err_of("f32") < 1e-5);
    assert!(err_of("r2f2") < 5e-3);
    assert!(err_of("E5M10") > 3.0 * err_of("r2f2"));
}

#[test]
fn config_roundtrip_through_toml_runs() {
    let cfg = ExperimentConfig::from_toml(
        r#"
        title = "it"
        app = "heat"
        backend = "r2f2:<3,9,3>"
        [heat]
        n = 65
        steps = 100
        dt = 6.1e-5
        "#,
    )
    .unwrap();
    let m = r2f2::metrics::Registry::new();
    let o = r2f2::coordinator::run_experiment(&cfg, &m);
    assert!(o.rel_err_vs_f64.is_finite());
    assert_eq!(o.muls, 3 * 63 * 100);
    // And a bogus backend spec errors.
    assert!(parse_backend("r2f2:<9,9,9>").is_err());
}
