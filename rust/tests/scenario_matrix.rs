//! The scenario-registry contract (DESIGN.md §11), enforced for **every**
//! entry of `pde::scenario::SCENARIOS` — adding a scenario to the registry
//! automatically enrolls it here (and in the CI scenario-matrix job, which
//! turns this suite's `MATRIX |` lines into a job-summary table):
//!
//! 1. **Engine bit-identity** — scalar dispatch ≡ carrier engine ≡ packed
//!    engine (fields, counters, mul counts) in both quantization modes,
//!    through the shared generic drivers.
//! 2. **MulOnly accuracy envelopes** — each scenario's 16-bit-class
//!    formats stay within their declared rel-L2 bound vs the f64
//!    reference, while the FP8 floor visibly fails where the physics says
//!    it must.
//! 3. **The adaptive envelope, generalized** — every scenario's default
//!    ladder widens out of its narrow rung in epoch 0 (retry discards the
//!    attempt), so the committed trajectory bit-equals the all-wide fixed
//!    run; scenarios that decay into a stall also narrow back, landing the
//!    same final RMSE at strictly lower modeled datapath cost (the PR-3
//!    heat envelope, now a property of the registry).

use r2f2::pde::adaptive::fixed_cost_lut;
use r2f2::pde::scenario::{ScenarioRun, ScenarioSize, SCENARIOS};
use r2f2::pde::{rmse, AdaptiveArith, BatchEngine, F64Arith, FixedArith, QuantMode};

fn assert_fields_bit_equal(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: node {i}: {} vs {}", a[i], b[i]);
    }
}

fn assert_runs_bit_equal(a: &ScenarioRun, b: &ScenarioRun, what: &str) {
    assert_fields_bit_equal(&a.field, &b.field, what);
    assert_eq!(a.muls, b.muls, "{what}: muls");
    assert_eq!(a.range_events, b.range_events, "{what}: events");
    assert_eq!(a.r2f2_stats, b.r2f2_stats, "{what}: stats");
}

#[test]
fn engines_bit_identical_for_every_scenario() {
    for spec in SCENARIOS {
        let fmt = spec.wide_format;
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            let mut scalar_be = FixedArith::new(fmt);
            let scalar = (spec.run)(ScenarioSize::Quick, &mut scalar_be, mode, false);
            let mut carrier_be = FixedArith::new(fmt).with_engine(BatchEngine::Carrier);
            let carrier = (spec.run)(ScenarioSize::Quick, &mut carrier_be, mode, true);
            let mut packed_be = FixedArith::new(fmt);
            let packed = (spec.run)(ScenarioSize::Quick, &mut packed_be, mode, true);

            assert_runs_bit_equal(&scalar, &carrier, &format!("{}/{mode:?} carrier", spec.name));
            assert_runs_bit_equal(&scalar, &packed, &format!("{}/{mode:?} packed", spec.name));
            println!(
                "MATRIX | {} | scalar=carrier=packed | {:?} | bit-identical |",
                spec.name, mode
            );
        }
    }
}

#[test]
fn mulonly_rmse_envelopes_hold_for_every_scenario() {
    for spec in SCENARIOS {
        let reference = (spec.run)(ScenarioSize::Accuracy, &mut F64Arith, QuantMode::MulOnly, true);
        for &(fmt, bound) in spec.envelopes {
            let mut be = FixedArith::new(fmt);
            let run = (spec.run)(ScenarioSize::Accuracy, &mut be, QuantMode::MulOnly, true);
            let err = r2f2::pde::rel_l2(&run.field, &reference.field);
            assert!(
                err < bound,
                "{}: {fmt} rel err {err} exceeds envelope {bound}",
                spec.name
            );
            println!(
                "MATRIX | {} | {fmt} mulonly | rel-err {err:.3e} | within {bound:.0e} |",
                spec.name
            );
        }
    }
}

#[test]
fn adaptive_envelope_generalizes_to_every_scenario() {
    for spec in SCENARIOS {
        let policy = (spec.adaptive_policy)();
        let narrow_fmt = policy.ladder[0];

        // Packed and scalar adaptive runs derive the same schedule and
        // bit-identical results (the decision inputs are bit-identical).
        let mut s_packed = AdaptiveArith::new(policy.clone());
        let packed =
            (spec.run_adaptive)(ScenarioSize::Adaptive, &mut s_packed, QuantMode::MulOnly, true);
        let mut s_scalar = AdaptiveArith::new(policy);
        let scalar =
            (spec.run_adaptive)(ScenarioSize::Adaptive, &mut s_scalar, QuantMode::MulOnly, false);
        assert_eq!(s_scalar.decisions(), s_packed.decisions(), "{}: decisions", spec.name);
        assert_eq!(s_scalar.trace(), s_packed.trace(), "{}: trace", spec.name);
        let what = format!("{} adaptive scalar vs packed", spec.name);
        assert_runs_bit_equal(&scalar, &packed, &what);

        let rep = s_packed.report();
        assert!(rep.widen_events >= 1, "{}: expected a widen: {:?}", spec.name, rep.trace);
        let want_final = if spec.expect_narrow { narrow_fmt } else { spec.wide_format };
        assert_eq!(rep.final_format, want_final, "{}", spec.name);

        // Epoch 0 widened and was retried from the pristine state, and any
        // narrow fired only in a stall — so the committed trajectory is the
        // all-wide fixed run, bit for bit, and the final RMSE matches it.
        let mut wide_be = FixedArith::new(spec.wide_format);
        let wide = (spec.run)(ScenarioSize::Adaptive, &mut wide_be, QuantMode::MulOnly, true);
        assert_fields_bit_equal(&packed.field, &wide.field, &format!("{} vs all-wide", spec.name));
        let reference = (spec.run)(ScenarioSize::Adaptive, &mut F64Arith, QuantMode::MulOnly, true);
        let rmse_adaptive = rmse(&packed.field, &reference.field);
        let rmse_wide = rmse(&wide.field, &reference.field);
        assert!(
            (rmse_adaptive - rmse_wide).abs() <= 1e-12,
            "{}: adaptive {rmse_adaptive} vs wide {rmse_wide}",
            spec.name
        );

        // Cost: strictly below the all-wide run whenever the ladder narrows
        // for the tail, and never below the all-narrow floor. (The floor
        // claim only makes sense for ladders whose narrow rung is the
        // cheaper one — swe2d's E5M10 → E6M9 exponent trade is not.)
        let cost_adaptive = rep.modeled_cost_lut;
        let cost_wide = fixed_cost_lut(spec.wide_format, wide.muls);
        if spec.expect_narrow {
            let cost_floor = fixed_cost_lut(narrow_fmt, wide.muls);
            assert!(cost_adaptive >= cost_floor, "{}: cost below floor", spec.name);
            assert!(rep.narrow_events >= 1, "{}: expected a narrow: {:?}", spec.name, rep.trace);
            assert!(
                cost_adaptive < cost_wide,
                "{}: adaptive cost {cost_adaptive} must beat all-wide {cost_wide}",
                spec.name
            );
        }
        println!(
            "MATRIX | {} | adaptive->{} | widen {} narrow {} | cost {:.3e} vs wide {:.3e} |",
            spec.name,
            rep.final_format,
            rep.widen_events,
            rep.narrow_events,
            cost_adaptive,
            cost_wide
        );
    }
}
