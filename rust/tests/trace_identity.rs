//! Trace-determinism and profile-guided-seeding contracts (DESIGN.md §17,
//! EXPERIMENTS.md E14), enforced for **every** entry of
//! `pde::scenario::SCENARIOS`:
//!
//! 1. **Content identity** — the wall-stripped trace projection
//!    (`Collector::content_ndjson`) is byte-identical across worker counts
//!    {1, 4} and shard counts {1, 3}, and attaching the collector leaves
//!    the solver output bit-identical to an untraced run. Trace content is
//!    a pure function of the experiment, not of the machine shape.
//! 2. **Profile-guided seeding** (ROADMAP item 4) — a Quick-size pilot
//!    recommends seeding the adaptive ladder at the wide rung for every
//!    scenario (all four narrow rungs overflow from the initial encode);
//!    the seeded run's committed trajectory bit-equals the all-wide fixed
//!    run while its modeled LUT cost is strictly below the cold-start
//!    adaptive run (which pays for the aborted epoch-0 narrow attempt) and
//!    never above the all-wide cost — strictly below it wherever the
//!    scenario decays into a narrowing stall.
//! 3. **Export schema** — every ndjson line parses with the crate's own
//!    JSON parser and the header carries `r2f2-trace/1`.
//!
//! The CI `trace-smoke` job greps this suite's `TRACE |` / `PROFILE |`
//! rows into its job summary.

use r2f2::pde::adaptive::fixed_cost_lut;
use r2f2::pde::scenario::{ScenarioSize, SCENARIOS};
use r2f2::pde::{rmse, AdaptiveArith, F64Arith, FixedArith, QuantMode};
use r2f2::trace::profile::run_pilot;
use r2f2::trace::{trace_scenario_adaptive, Collector};

fn assert_fields_bit_equal(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: node {i}: {} vs {}", a[i], b[i]);
    }
}

/// Run `f` with `R2F2_WORKERS` pinned to `n`, restoring the prior value.
/// `default_workers` re-reads the variable on every call, so the override
/// takes effect immediately for the pool underneath sharded runs.
fn with_workers<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let prev = std::env::var("R2F2_WORKERS").ok();
    std::env::set_var("R2F2_WORKERS", n.to_string());
    let out = f();
    match prev {
        Some(v) => std::env::set_var("R2F2_WORKERS", v),
        None => std::env::remove_var("R2F2_WORKERS"),
    }
    out
}

#[test]
fn trace_content_is_worker_and_shard_invariant_and_nonperturbing() {
    for spec in SCENARIOS {
        // Untraced baseline: the epoch hook and collector must not perturb
        // the committed trajectory by a single bit.
        let mut plain_sched = AdaptiveArith::new((spec.adaptive_policy)());
        let plain =
            (spec.run_adaptive)(ScenarioSize::Adaptive, &mut plain_sched, QuantMode::MulOnly, true);

        let mut legs: Vec<(usize, usize, String)> = Vec::new();
        for workers in [1usize, 4] {
            with_workers(workers, || {
                for shards in [1usize, 3] {
                    let collector = Collector::new();
                    let (run, report) = trace_scenario_adaptive(
                        spec,
                        ScenarioSize::Adaptive,
                        QuantMode::MulOnly,
                        true,
                        shards,
                        &collector,
                    );
                    let what = format!("{} w{workers} s{shards}", spec.name);
                    assert_fields_bit_equal(&run.field, &plain.field, &what);
                    assert_eq!(run.muls, plain.muls, "{what}: muls");
                    assert_eq!(collector.dropped(), 0, "{what}: ring overflowed");
                    assert!(report.epochs > 0, "{what}: no epochs committed");
                    legs.push((workers, shards, collector.content_ndjson()));
                }
            });
        }

        let (w0, s0, first) = &legs[0];
        for (w, s, content) in &legs[1..] {
            assert_eq!(
                content, first,
                "{}: trace content diverges between workers={w0} shards={s0} and workers={w} shards={s}",
                spec.name
            );
        }
        assert!(first.contains("\"adaptive.epoch\""), "{}: no epoch spans", spec.name);
        assert!(first.contains("\"scenario.done\""), "{}: no terminal span", spec.name);
        assert!(!first.contains("wall_ns"), "{}: wall clock leaked into content", spec.name);

        // ndjson: one header line plus one line per event.
        let events = first.lines().count() - 1;
        println!(
            "TRACE | {} | {events} events | content byte-identical across workers {{1,4}} x shards {{1,3}} | untraced run bit-equal |",
            spec.name
        );
    }
}

#[test]
fn profile_seeded_adaptive_matches_wide_rmse_at_lower_cost() {
    for spec in SCENARIOS {
        let plan = run_pilot(spec, None);
        assert_eq!(
            plan.seed_rung, 1,
            "{}: pilot should recommend the wide rung (narrow overflows at Quick size)",
            spec.name
        );

        // Cold-start adaptive: pays for the aborted epoch-0 narrow attempt.
        let mut cold_sched = AdaptiveArith::new((spec.adaptive_policy)());
        let cold =
            (spec.run_adaptive)(ScenarioSize::Adaptive, &mut cold_sched, QuantMode::MulOnly, true);
        let cold_report = cold_sched.report();
        assert!(cold_report.widen_events >= 1, "{}: cold start never widened", spec.name);

        // Profile-seeded adaptive: same ladder, start rung from the pilot.
        let mut seeded_sched = AdaptiveArith::new(plan.seeded_policy(spec));
        let seeded =
            (spec.run_adaptive)(ScenarioSize::Adaptive, &mut seeded_sched, QuantMode::MulOnly, true);
        let seeded_report = seeded_sched.report();

        // All-wide fixed reference and the f64 ground truth.
        let mut wide_be = FixedArith::new(spec.wide_format);
        let wide = (spec.run)(ScenarioSize::Adaptive, &mut wide_be, QuantMode::MulOnly, true);
        let mut f64_be = F64Arith;
        let reference = (spec.run)(ScenarioSize::Adaptive, &mut f64_be, QuantMode::MulOnly, true);

        // The seeded committed trajectory is the all-wide trajectory (for
        // non-narrowing scenarios bit-for-bit; narrowing scenarios land the
        // identical final RMSE — same envelope scenario_matrix enforces for
        // the cold adaptive run, inherited by seeding at the same rung the
        // cold run widens into).
        let rmse_seeded = rmse(&seeded.field, &reference.field);
        let rmse_cold = rmse(&cold.field, &reference.field);
        let rmse_wide = rmse(&wide.field, &reference.field);
        assert_fields_bit_equal(&seeded.field, &cold.field, &format!("{} seeded vs cold", spec.name));
        assert_eq!(
            rmse_seeded.to_bits(),
            rmse_wide.to_bits(),
            "{}: seeded RMSE {rmse_seeded:.6e} != all-wide RMSE {rmse_wide:.6e}",
            spec.name
        );
        assert_eq!(rmse_cold.to_bits(), rmse_wide.to_bits(), "{}: cold RMSE drifted", spec.name);

        // Cost: seeding skips the aborted narrow attempt, so the modeled
        // LUT cost is strictly below cold start and never above all-wide.
        let cost_wide = fixed_cost_lut(spec.wide_format, wide.muls);
        assert!(
            seeded_report.modeled_cost_lut < cold_report.modeled_cost_lut,
            "{}: seeded cost {:.6e} not strictly below cold-start {:.6e}",
            spec.name,
            seeded_report.modeled_cost_lut,
            cold_report.modeled_cost_lut
        );
        assert!(
            seeded_report.modeled_cost_lut <= cost_wide * (1.0 + 1e-12),
            "{}: seeded cost {:.6e} above all-wide {:.6e}",
            spec.name,
            seeded_report.modeled_cost_lut,
            cost_wide
        );
        if spec.expect_narrow {
            assert!(
                seeded_report.narrow_events >= 1,
                "{}: expected the seeded run to narrow after the stall",
                spec.name
            );
            assert!(
                seeded_report.modeled_cost_lut < cost_wide,
                "{}: narrowing scenario should undercut all-wide cost",
                spec.name
            );
        }

        println!(
            "PROFILE | {} | seed rung {} ({}) | rmse {:.3e} == all-wide | cost {:.3e} < cold {:.3e} (wide {:.3e}) |",
            spec.name,
            plan.seed_rung,
            plan.recommended().format,
            rmse_seeded,
            seeded_report.modeled_cost_lut,
            cold_report.modeled_cost_lut,
            cost_wide
        );
    }
}

#[test]
fn trace_export_parses_and_carries_the_schema() {
    let spec = &SCENARIOS[0];
    let collector = Collector::new();
    let _ = trace_scenario_adaptive(
        spec,
        ScenarioSize::Adaptive,
        QuantMode::MulOnly,
        true,
        1,
        &collector,
    );
    let text = collector.to_ndjson();
    let mut lines = text.lines();

    let header = r2f2::config::parse_json(lines.next().expect("header line"))
        .expect("header line is valid JSON");
    assert_eq!(header.get("schema").and_then(|v| v.as_str()), Some("r2f2-trace/1"));
    assert_eq!(
        header.get("events").and_then(|v| v.as_f64()),
        Some(collector.len() as f64),
        "header event count"
    );
    assert_eq!(header.get("dropped").and_then(|v| v.as_f64()), Some(0.0));

    let mut n = 0usize;
    for line in lines {
        let event = r2f2::config::parse_json(line)
            .unwrap_or_else(|e| panic!("event line is not valid JSON ({e}): {line}"));
        for key in ["lane", "seq", "name", "step", "epoch", "muls", "fields"] {
            assert!(event.get(key).is_some(), "event missing {key:?}: {line}");
        }
        n += 1;
    }
    assert_eq!(n, collector.len(), "line count matches collector");

    // The content projection differs from the full export only by wall
    // attachments — this run records none, so the bodies agree.
    let content = collector.content_ndjson();
    assert_eq!(
        text.lines().skip(1).collect::<Vec<_>>(),
        content.lines().skip(1).collect::<Vec<_>>(),
        "no wall attachments expected on the scenario lane"
    );
}
