//! The batched-engine contract (DESIGN.md §8): for every backend, a run
//! through the batched API must produce **bit-identical** fields and
//! **identical** counters (`muls`, R2F2 `Stats`, fixed-format
//! `RangeEvents`) to the per-multiplication scalar-dispatch reference.
//!
//! This is what makes the engine an *optimization* rather than a semantic
//! change: every accuracy figure in EXPERIMENTS.md is measured on the fast
//! path but specified by the scalar path.

use r2f2::pde::heat1d::{self, HeatParams};
use r2f2::pde::init::HeatInit;
use r2f2::pde::swe2d::{self, QuantScope, SweParams};
use r2f2::pde::{
    Arith, BatchEngine, F32Arith, F64Arith, FixedArith, QuantMode, R2f2Arith, StochasticArith,
};
use r2f2::r2f2core::R2f2Config;
use r2f2::softfloat::FpFormat;

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(
            a[i].to_bits(),
            b[i].to_bits(),
            "{what}: lane {i}: scalar {} vs batched {}",
            a[i],
            b[i]
        );
    }
}

/// Every backend under test, freshly constructed per call so scalar and
/// batched runs start from identical state. Both batched engines are
/// represented: the default packed engine (DESIGN.md §9) and the frozen
/// PR-1 carrier engine.
#[allow(clippy::type_complexity)]
fn backends() -> Vec<(&'static str, Box<dyn Fn() -> Box<dyn Arith>>)> {
    vec![
        ("f64", Box::new(|| Box::new(F64Arith) as Box<dyn Arith>)),
        ("f32", Box::new(|| Box::new(F32Arith) as Box<dyn Arith>)),
        ("fixed E5M10", Box::new(|| Box::new(FixedArith::new(FpFormat::E5M10)) as Box<dyn Arith>)),
        (
            "fixed E5M10 carrier",
            Box::new(|| {
                Box::new(FixedArith::new(FpFormat::E5M10).with_engine(BatchEngine::Carrier))
                    as Box<dyn Arith>
            }),
        ),
        ("fixed E6M9", Box::new(|| Box::new(FixedArith::new(FpFormat::new(6, 9))) as Box<dyn Arith>)),
        ("r2f2 <3,9,3>", Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_393)) as Box<dyn Arith>)),
        (
            "r2f2 <3,9,3> carrier",
            Box::new(|| {
                Box::new(R2f2Arith::new(R2f2Config::C16_393).with_engine(BatchEngine::Carrier))
                    as Box<dyn Arith>
            }),
        ),
        ("r2f2 <3,8,4>", Box::new(|| Box::new(R2f2Arith::new(R2f2Config::C16_384)) as Box<dyn Arith>)),
        ("E5M10-sr", Box::new(|| Box::new(StochasticArith::new(FpFormat::E5M10, 11)) as Box<dyn Arith>)),
    ]
}

fn check_heat(p: &HeatParams, mode: QuantMode, ctx: &str) {
    for (name, mk) in &backends() {
        let mut scalar_be = mk();
        let mut batched_be = mk();
        let s = heat1d::run_scalar(p, scalar_be.as_mut(), mode);
        let b = heat1d::run(p, batched_be.as_mut(), mode);
        let what = format!("{ctx}/{name}/{mode:?}");
        assert_bits_eq(&s.u, &b.u, &what);
        assert_eq!(s.muls, b.muls, "{what}: muls");
        assert_eq!(s.muls, p.expected_muls(), "{what}: expected muls");
        assert_eq!(s.backend, b.backend, "{what}: backend name");
        assert_eq!(s.r2f2_stats, b.r2f2_stats, "{what}: r2f2 stats");
        assert_eq!(s.range_events, b.range_events, "{what}: range events");
        assert_eq!(s.snapshots.len(), b.snapshots.len(), "{what}: snapshots");
        for (i, ((ss, su), (bs, bu))) in s.snapshots.iter().zip(b.snapshots.iter()).enumerate() {
            assert_eq!(ss, bs, "{what}: snapshot step {i}");
            assert_bits_eq(su, bu, &format!("{what}: snapshot {i}"));
        }
    }
}

#[test]
fn heat_bit_identical_mul_only() {
    let p = HeatParams {
        n: 101,
        dt: 0.25 / (100.0f64 * 100.0),
        steps: 400,
        snapshot_every: 100,
        ..HeatParams::default()
    };
    check_heat(&p, QuantMode::MulOnly, "heat");
}

#[test]
fn heat_bit_identical_full_mode() {
    let p = HeatParams { n: 101, dt: 0.25 / (100.0f64 * 100.0), steps: 300, ..HeatParams::default() };
    check_heat(&p, QuantMode::Full, "heat-full");
}

#[test]
fn heat_bit_identical_in_the_underflow_regime() {
    // §3.1's failure regime: a tiny field drives the fixed format's
    // products below the min normal, so the deduplicated fast path must
    // reproduce the scalar event *multiplicity*, not just event presence.
    let p = HeatParams {
        n: 101,
        dt: 0.25 / (100.0f64 * 100.0),
        steps: 200,
        init: HeatInit::Sin { amplitude: 5e-4, cycles: 2.0 },
        ..HeatParams::default()
    };
    let mut probe = FixedArith::new(FpFormat::E5M10);
    let events = heat1d::run(&p, &mut probe, QuantMode::MulOnly).range_events.unwrap();
    assert!(events.underflows > 0, "regime must actually underflow");
    check_heat(&p, QuantMode::MulOnly, "heat-tiny");
}

#[test]
fn heat_bit_identical_in_the_overflow_regime() {
    let p = HeatParams {
        n: 101,
        dt: 0.25 / (100.0f64 * 100.0),
        steps: 100,
        init: HeatInit::Sin { amplitude: 2.5e5, cycles: 2.0 },
        ..HeatParams::default()
    };
    let mut probe = FixedArith::new(FpFormat::E5M10);
    let events = heat1d::run(&p, &mut probe, QuantMode::MulOnly).range_events.unwrap();
    assert!(events.overflows > 0, "regime must actually overflow");
    check_heat(&p, QuantMode::MulOnly, "heat-huge");
}

#[test]
fn swe_bit_identical_both_scopes() {
    let p = SweParams { steps: 25, ..SweParams::default() };
    for scope in [QuantScope::UxFluxOnly, QuantScope::AllFluxMuls] {
        for (name, mk) in &backends() {
            let mut scalar_be = mk();
            let mut batched_be = mk();
            let s = swe2d::run_scalar(&p, scalar_be.as_mut(), scope);
            let b = swe2d::run(&p, batched_be.as_mut(), scope);
            let what = format!("swe/{name}/{scope:?}");
            assert_bits_eq(&s.h, &b.h, &format!("{what}: h"));
            assert_bits_eq(&s.u, &b.u, &format!("{what}: u"));
            assert_bits_eq(&s.v, &b.v, &format!("{what}: v"));
            assert_eq!(s.muls, b.muls, "{what}: muls");
            assert_eq!(s.r2f2_stats, b.r2f2_stats, "{what}: r2f2 stats");
            assert_eq!(s.range_events, b.range_events, "{what}: range events");
            assert_eq!(s.mass_drift.to_bits(), b.mass_drift.to_bits(), "{what}: mass drift");
        }
    }
}

#[test]
fn swe_bit_identical_full_mode() {
    // QuantMode::Full on the shallow-water flux (the adder ablation): the
    // final combine of every quantized flux runs through the backend too,
    // and the batched engines must still replay the scalar stream exactly —
    // including the stochastic-rounding backend, whose RNG draw sequence is
    // part of the contract.
    let p = SweParams { steps: 20, ..SweParams::default() };
    for scope in [QuantScope::UxFluxOnly, QuantScope::AllFluxMuls] {
        for (name, mk) in &backends() {
            let mut scalar_be = mk();
            let mut batched_be = mk();
            let s = swe2d::run_scalar_mode(&p, scalar_be.as_mut(), scope, QuantMode::Full);
            let b = swe2d::run_mode(&p, batched_be.as_mut(), scope, QuantMode::Full);
            let what = format!("swe-full/{name}/{scope:?}");
            assert_bits_eq(&s.h, &b.h, &format!("{what}: h"));
            assert_bits_eq(&s.u, &b.u, &format!("{what}: u"));
            assert_bits_eq(&s.v, &b.v, &format!("{what}: v"));
            assert_eq!(s.muls, b.muls, "{what}: muls");
            assert_eq!(s.r2f2_stats, b.r2f2_stats, "{what}: r2f2 stats");
            assert_eq!(s.range_events, b.range_events, "{what}: range events");
            assert_eq!(s.mass_drift.to_bits(), b.mass_drift.to_bits(), "{what}: mass drift");
        }
    }
}

#[test]
fn heat_bit_identical_full_mode_stochastic_long_run() {
    // Stochastic rounding consumes one RNG draw per inexact rounding, so a
    // long Full-mode run is the sharpest detector of any packed/batched
    // path issuing a different operation stream.
    let p = HeatParams { n: 65, dt: 0.25 / (64.0f64 * 64.0), steps: 800, ..HeatParams::default() };
    for mode in [QuantMode::MulOnly, QuantMode::Full] {
        let mut a = StochasticArith::new(FpFormat::E5M10, 0x5EED);
        let mut b = StochasticArith::new(FpFormat::E5M10, 0x5EED);
        let s = heat1d::run_scalar(&p, &mut a, mode);
        let g = heat1d::run(&p, &mut b, mode);
        assert_bits_eq(&s.u, &g.u, &format!("stochastic-{mode:?}"));
        assert_eq!(s.range_events, g.range_events, "stochastic-{mode:?}: events");
    }
}

#[test]
fn r2f2_batched_heat_still_adjusts_rarely() {
    // The batched fast path reuses adjustment decisions across blocks; the
    // paper's §5.3 observation (a handful of adjustments per 1.5M muls)
    // must survive verbatim since the state machine is bit-identical.
    let p = HeatParams { n: 101, dt: 0.25 / (100.0f64 * 100.0), steps: 1500, ..HeatParams::default() };
    let mut be = R2f2Arith::new(R2f2Config::C16_393);
    let res = heat1d::run(&p, &mut be, QuantMode::MulOnly);
    let st = res.r2f2_stats.unwrap();
    assert_eq!(st.muls, p.expected_muls());
    let adj = st.overflow_adjustments + st.redundancy_adjustments;
    assert!(adj < st.muls / 100, "adjustments must stay rare: {adj} of {}", st.muls);
}
