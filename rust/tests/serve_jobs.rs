//! Async job API contract suite (DESIGN.md §16): the checkpointed-epoch
//! executor driven end-to-end over HTTP.
//!
//! The load-bearing property is the same one `serve_loopback.rs` pins for
//! `/v1/run`: **a job's fetched result is byte-identical to a direct
//! `run_experiment` of the same config** — through epoch chunking, event
//! streaming, pause/resume parking and an injected worker panic resumed
//! from the checkpoint. Plus the operational contracts: the job store's
//! live cap answers 503, progress is queryable while the run computes,
//! and the event stream terminates exactly when the job does.
//!
//! The server runs `coordinator::default_workers()` threads, so the CI
//! `jobs-smoke` matrix exercises this suite at `R2F2_WORKERS=1` (every
//! epoch and every HTTP request interleave on one worker) and `=4`
//! (continuations migrate between workers). Tests print machine-greppable
//! `SERVE |` rows for the CI job summary.

use r2f2::config::{parse_json, ExperimentConfig};
use r2f2::coordinator::{default_workers, run_experiment};
use r2f2::metrics::Registry;
use r2f2::server::{http, outcome_json, ServeOptions, Server};
use std::time::Duration;

fn start(jobs_cap: usize) -> Server {
    Server::start(ServeOptions {
        port: 0,
        workers: default_workers(),
        queue_cap: 32,
        cache_cap: 32,
        keepalive_ms: 5000,
        jobs_cap,
    })
    .expect("server binds port 0")
}

/// What the job's result must byte-equal, computed directly. Job-only
/// sections (`job`, `fault`) are ignored by the config parser, so the
/// same body works for both paths.
fn expected_response(body: &str) -> String {
    let cfg = ExperimentConfig::from_json(&parse_json(body).unwrap()).unwrap();
    outcome_json(&run_experiment(&cfg, &Registry::new()))
}

/// Submit a job, return its id (asserting the 202 contract).
fn submit(addr: std::net::SocketAddr, body: &str) -> String {
    let resp = http::request(addr, "POST", "/v1/jobs", body.as_bytes()).unwrap();
    assert_eq!(resp.status, 202, "{}", resp.text());
    let j = parse_json(&resp.text()).unwrap();
    let id = j.get("id").unwrap().as_str().unwrap().to_string();
    assert_eq!(resp.header("x-r2f2-job"), Some(id.as_str()));
    assert_eq!(
        j.get("result").unwrap().as_str(),
        Some(format!("/v1/jobs/{id}/result").as_str()),
        "submit echoes the resource links"
    );
    id
}

/// Poll `GET /result` until 200 (409 is the only acceptable interim).
fn poll_result(addr: std::net::SocketAddr, id: &str) -> http::Response {
    let path = format!("/v1/jobs/{id}/result");
    for _ in 0..4000 {
        let r = http::request(addr, "GET", &path, b"").unwrap();
        if r.status == 200 {
            return r;
        }
        assert_eq!(r.status, 409, "only 'not finished' is acceptable while polling: {}", r.text());
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("job {id} never finished");
}

#[test]
fn streamed_job_completes_and_result_is_byte_identical() {
    let server = start(8);
    let addr = server.addr();
    // 48 steps in epochs of 10 → 5 epochs (the last one short).
    let body = r#"{"title": "stream-test", "app": "heat", "backend": "fixed:E5M10",
                   "heat": {"n": 33, "steps": 48, "dt": 2.4e-4},
                   "job": {"epoch_steps": 10}}"#;
    let id = submit(addr, body);

    // Follow the event stream to the job's terminal state: chunked
    // ndjson on a dedicated streamer thread, ending when the job does.
    let mut c = http::Client::connect(addr).unwrap();
    c.send_only("GET", &format!("/v1/jobs/{id}/events"), b"", false).unwrap();
    let (status, headers) = c.recv_stream_head().unwrap();
    assert_eq!(status, 200);
    assert!(
        headers.iter().any(|(k, v)| k == "transfer-encoding" && v.contains("chunked")),
        "events stream chunked: {headers:?}"
    );
    let mut streamed = String::new();
    while let Some(chunk) = c.recv_chunk().unwrap() {
        streamed.push_str(&String::from_utf8(chunk).unwrap());
    }
    let lines: Vec<&str> = streamed.lines().collect();
    assert!(lines[0].contains("\"event\": \"submitted\""), "{streamed}");
    let epochs = lines.iter().filter(|l| l.contains("\"event\": \"epoch\"")).count();
    assert_eq!(epochs, 5, "48 steps / 10 per epoch = 5 epochs:\n{streamed}");
    assert!(lines.last().unwrap().contains("\"event\": \"done\""), "{streamed}");
    // Per-epoch telemetry carries the adaptive scheduler's observables.
    let epoch_line = lines.iter().find(|l| l.contains("\"event\": \"epoch\"")).unwrap();
    for field in ["steps_done", "muls", "overflows", "underflows", "min_abs", "max_abs"] {
        assert!(epoch_line.contains(field), "epoch event missing {field}: {epoch_line}");
    }
    // Every event line is well-formed JSON.
    for l in &lines {
        assert!(parse_json(l).is_ok(), "unparseable event: {l}");
    }

    // The stream ended ⇒ the job is done ⇒ the result is ready *now*.
    let status = http::request(addr, "GET", &format!("/v1/jobs/{id}"), b"").unwrap();
    let sj = parse_json(&status.text()).unwrap();
    assert_eq!(sj.get("state").unwrap().as_str(), Some("done"));
    assert_eq!(sj.get("steps_done").unwrap().as_usize(), Some(48));
    let result = http::request(addr, "GET", &format!("/v1/jobs/{id}/result"), b"").unwrap();
    assert_eq!(result.status, 200);
    assert_eq!(
        result.text(),
        expected_response(body),
        "chunked-epoch job result must byte-equal the direct run"
    );
    println!(
        "SERVE | jobs stream | {} workers | {epochs} epochs, {} events | byte-identical ok |",
        default_workers(),
        lines.len()
    );
    server.shutdown();
}

#[test]
fn crash_resumed_job_is_byte_identical_over_http() {
    let server = start(8);
    let addr = server.addr();
    // The worker owning epoch 2 panics; the next epoch replays from the
    // epoch-1 checkpoint and the job still lands on identical bytes.
    let body = r#"{"app": "heat", "backend": "fixed:E5M10",
                   "heat": {"n": 33, "steps": 48, "dt": 2.4e-4},
                   "job": {"epoch_steps": 10},
                   "fault": {"panic_at_epoch": 2}}"#;
    let id = submit(addr, body);
    let result = poll_result(addr, &id);
    assert_eq!(
        result.text(),
        expected_response(body),
        "crash-resumed job result must byte-equal the direct run"
    );

    let status = http::request(addr, "GET", &format!("/v1/jobs/{id}"), b"").unwrap();
    let sj = parse_json(&status.text()).unwrap();
    assert_eq!(sj.get("attempts").unwrap().as_usize(), Some(1), "exactly one crash survived");

    // The full event log (the stream of a terminal job returns at once)
    // records the resume point.
    let mut c = http::Client::connect(addr).unwrap();
    c.send_only("GET", &format!("/v1/jobs/{id}/events"), b"", false).unwrap();
    let (st, _) = c.recv_stream_head().unwrap();
    assert_eq!(st, 200);
    let mut streamed = String::new();
    while let Some(chunk) = c.recv_chunk().unwrap() {
        streamed.push_str(&String::from_utf8(chunk).unwrap());
    }
    assert!(
        streamed.contains("\"event\": \"crash_resumed\""),
        "resume must be visible in the event log:\n{streamed}"
    );
    let snap = server.metrics_snapshot();
    assert_eq!(snap.counter("serve.jobs.panics"), 1);
    assert_eq!(snap.counter("serve.jobs.crash_resumes"), 1);
    println!(
        "SERVE | jobs crash-resume | {} workers | 1 panic survived | byte-identical ok |",
        default_workers()
    );
    server.shutdown();
}

#[test]
fn pause_parks_and_resume_finishes_over_http() {
    let server = start(8);
    let addr = server.addr();
    // Long enough that the pause lands mid-run: ~1.5M quantized muls in
    // 1000 four-step epochs (tens of ms in release, ~a second in debug).
    let body = r#"{"app": "heat", "backend": "fixed:E5M10",
                   "heat": {"n": 129, "dt": 0.0000152587890625, "steps": 4000},
                   "job": {"epoch_steps": 4}}"#;
    let id = submit(addr, body);

    let paused = http::request(addr, "POST", &format!("/v1/jobs/{id}/pause"), b"").unwrap();
    assert_eq!(paused.status, 200, "{}", paused.text());
    assert_eq!(
        parse_json(&paused.text()).unwrap().get("state").unwrap().as_str(),
        Some("paused")
    );
    // Any in-flight epoch finishes and parks; after that, progress freezes.
    std::thread::sleep(Duration::from_millis(150));
    let s1 = http::request(addr, "GET", &format!("/v1/jobs/{id}"), b"").unwrap().text();
    std::thread::sleep(Duration::from_millis(150));
    let s2 = http::request(addr, "GET", &format!("/v1/jobs/{id}"), b"").unwrap().text();
    let steps = |s: &str| parse_json(s).unwrap().get("steps_done").unwrap().as_usize().unwrap();
    assert_eq!(steps(&s1), steps(&s2), "a paused job must not advance: {s1} vs {s2}");
    assert!(steps(&s1) < 4000, "pause must land before completion");

    let resumed = http::request(addr, "POST", &format!("/v1/jobs/{id}/resume"), b"").unwrap();
    assert_eq!(resumed.status, 200, "{}", resumed.text());
    let result = poll_result(addr, &id);
    assert_eq!(
        result.text(),
        expected_response(body),
        "paused-and-resumed job result must byte-equal the direct run"
    );
    // Terminal jobs answer 409 to further pause/resume.
    let r = http::request(addr, "POST", &format!("/v1/jobs/{id}/pause"), b"").unwrap();
    assert_eq!(r.status, 409);
    println!(
        "SERVE | jobs pause/resume | {} workers | parked at step {} of 4000 | byte-identical ok |",
        default_workers(),
        steps(&s1)
    );
    server.shutdown();
}

#[test]
fn job_store_cap_answers_503_and_unknown_jobs_404() {
    let server = start(2);
    let addr = server.addr();
    // Two slow live jobs fill the cap=2 store.
    let slow = r#"{"app": "heat", "backend": "fixed:E5M10",
                   "heat": {"n": 129, "dt": 0.0000152587890625, "steps": 4000},
                   "job": {"epoch_steps": 1}}"#;
    let a = submit(addr, slow);
    let b = submit(addr, slow);
    assert_ne!(a, b);
    let full = http::request(addr, "POST", "/v1/jobs", slow.as_bytes()).unwrap();
    assert_eq!(full.status, 503, "live cap must reject: {}", full.text());
    assert!(full.text().contains("job store full"));

    // Unknown ids are 404 on every job route.
    for (method, path) in [
        ("GET", "/v1/jobs/job-999".to_string()),
        ("GET", "/v1/jobs/job-999/result".to_string()),
        ("GET", "/v1/jobs/job-999/events".to_string()),
        ("POST", "/v1/jobs/job-999/pause".to_string()),
        ("POST", "/v1/jobs/job-999/resume".to_string()),
    ] {
        let r = http::request(addr, method, &path, b"").unwrap();
        assert_eq!(r.status, 404, "{method} {path}: {}", r.text());
    }
    // Wrong methods are 405, not 404.
    let r = http::request(addr, "GET", &format!("/v1/jobs/{a}/pause"), b"").unwrap();
    assert_eq!(r.status, 405);
    let r = http::request(addr, "POST", &format!("/v1/jobs/{a}/result"), b"").unwrap();
    assert_eq!(r.status, 405);
    println!("SERVE | jobs limits | cap 2 | 503 at capacity, 404/405 contracts ok |");
    server.shutdown();
}

#[test]
fn status_is_queryable_while_the_job_computes() {
    let server = start(8);
    let addr = server.addr();
    let body = r#"{"app": "heat", "backend": "fixed:E5M10",
                   "heat": {"n": 129, "dt": 0.0000152587890625, "steps": 4000},
                   "job": {"epoch_steps": 4}}"#;
    let id = submit(addr, body);
    // Even at R2F2_WORKERS=1, status answers *during* the run, because
    // epoch continuations queue behind admitted connections.
    let mut mid_run = false;
    for _ in 0..2000 {
        let s = http::request(addr, "GET", &format!("/v1/jobs/{id}"), b"").unwrap();
        assert_eq!(s.status, 200);
        let j = parse_json(&s.text()).unwrap();
        let done = j.get("steps_done").unwrap().as_usize().unwrap();
        let state = j.get("state").unwrap().as_str().unwrap().to_string();
        if state == "done" {
            break;
        }
        if done > 0 {
            mid_run = true; // a progress reading strictly between 0 and done
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let result = poll_result(addr, &id);
    assert_eq!(result.text(), expected_response(body));
    assert!(mid_run, "progress must be observable mid-run");
    println!(
        "SERVE | jobs progress | {} workers | mid-run status ok | byte-identical ok |",
        default_workers()
    );
    server.shutdown();
}
