//! Cross-layer bit-exactness: the AOT-lowered Pallas kernels executed via
//! PJRT must agree **bit-for-bit** with the rust scalar implementation of
//! DESIGN.md §3. This is the contract that lets accuracy results measured
//! natively (sweeps, case studies) transfer to the compiled artifacts.
//!
//! Requires `make artifacts`; tests skip politely when artifacts are absent
//! (e.g. a cargo-only CI lane).

use r2f2::r2f2core::{mul_packed, R2f2Config, R2f2Multiplier};
use r2f2::rng::SplitMix64;
use r2f2::runtime::Runtime;
use r2f2::softfloat::{decode, encode, Rounder};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::from_default_dir() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

/// Random f32 operands covering the full sweep range plus specials.
fn operands(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let mut a = Vec::with_capacity(n);
    let mut b = Vec::with_capacity(n);
    for i in 0..n {
        if i < 8 {
            // Edge lanes: zeros, signed zeros, huge, tiny.
            let specials = [0.0f32, -0.0, 1.0, -1.0, 65504.0, 1e-7, 3.0e9, 5e-39];
            a.push(specials[i]);
            b.push(specials[(i + 3) % 8]);
        } else {
            let sa = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
            a.push((rng.log_uniform(1e-9, 1e9) * sa) as f32);
            b.push(rng.log_uniform(1e-9, 1e9) as f32);
        }
    }
    (a, b)
}

/// Rust scalar path for a fixed split: encode(f32) → truncated mul → decode.
fn rust_mul_at_split(a: f32, b: f32, cfg: R2f2Config, k: u32) -> f32 {
    let fmt = cfg.format(k);
    let mut r = Rounder::nearest_even();
    let (fa, _) = encode(a as f64, fmt, &mut r);
    let (fb, _) = encode(b as f64, fmt, &mut r);
    let (fc, _) = mul_packed(fa, fb, cfg, k, &mut r);
    decode(fc, fmt) as f32
}

#[test]
fn pallas_fixed_split_k2_is_bit_exact() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.elemwise_n;
    let exe = rt.load("r2f2_mul_k2").unwrap();
    let (a, b) = operands(n, 0xA0);
    let got = exe.run_f32(&[Runtime::lit_f32(&a), Runtime::lit_f32(&b)], 0).unwrap();
    let cfg = R2f2Config::C16_393;
    for i in 0..n {
        let want = rust_mul_at_split(a[i], b[i], cfg, 2);
        assert_eq!(
            got[i].to_bits(),
            want.to_bits(),
            "lane {i}: {} × {} → pallas {} vs rust {}",
            a[i],
            b[i],
            got[i],
            want
        );
    }
}

#[test]
fn pallas_fixed_split_k0_truncation_path_is_bit_exact() {
    // k=0 exercises the maximum flexible-partial-product truncation.
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.elemwise_n;
    let exe = rt.load("r2f2_mul_k0").unwrap();
    let (a, b) = operands(n, 0xB1);
    let got = exe.run_f32(&[Runtime::lit_f32(&a), Runtime::lit_f32(&b)], 0).unwrap();
    let cfg = R2f2Config::C16_393;
    for i in 0..n {
        let want = rust_mul_at_split(a[i], b[i], cfg, 0);
        assert_eq!(got[i].to_bits(), want.to_bits(), "lane {i}: {} × {}", a[i], b[i]);
    }
}

#[test]
fn pallas_adaptive_unit_matches_rust_multiplier_state_machine() {
    // Full adjustment-unit semantics: result, final split, streak and all
    // three counters must match rust's R2f2Multiplier per lane.
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.elemwise_n;
    let exe = rt.load("r2f2_mul_adaptive").unwrap();
    let cfg = R2f2Config::C16_393;
    let (a, b) = operands(n, 0xC2);
    let mut rng = SplitMix64::new(0xD3);
    let k0: Vec<i32> = (0..n).map(|_| rng.below(cfg.fx as u64 + 1) as i32).collect();
    let s0 = vec![0i32; n];

    let outs = exe
        .run(&[
            Runtime::lit_f32(&a),
            Runtime::lit_f32(&b),
            Runtime::lit_i32(&k0),
            Runtime::lit_i32(&s0),
        ])
        .unwrap();
    let res: Vec<f32> = outs[0].to_vec().unwrap();
    let k1: Vec<i32> = outs[1].to_vec().unwrap();
    let s1: Vec<i32> = outs[2].to_vec().unwrap();
    let widen: Vec<i32> = outs[3].to_vec().unwrap();
    let narrow: Vec<i32> = outs[4].to_vec().unwrap();
    let unresolved: Vec<i32> = outs[5].to_vec().unwrap();

    for i in 0..n {
        let mut unit = R2f2Multiplier::with_split(cfg, k0[i] as u32);
        let want = unit.mul(a[i] as f64, b[i] as f64) as f32;
        assert_eq!(res[i].to_bits(), want.to_bits(), "lane {i}: {} × {}", a[i], b[i]);
        assert_eq!(k1[i] as u32, unit.split(), "lane {i} split");
        assert_eq!(s1[i] as u32, unit.streak(), "lane {i} streak");
        let st = unit.stats();
        assert_eq!(widen[i] as u64, st.overflow_adjustments, "lane {i} widen");
        assert_eq!(narrow[i] as u64, st.redundancy_adjustments, "lane {i} narrow");
        assert_eq!(unresolved[i] as u64, st.unresolved_range_events, "lane {i} unresolved");
    }
}

#[test]
fn pallas_quantizer_matches_rust_softfloat() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.elemwise_n;
    let exe = rt.load("quantize_e5m10").unwrap();
    let (x, _) = operands(n, 0xE4);
    let got = exe.run_f32(&[Runtime::lit_f32(&x)], 0).unwrap();
    let fmt = r2f2::softfloat::FpFormat::E5M10;
    for i in 0..n {
        let want = r2f2::softfloat::quantize(x[i] as f64, fmt) as f32;
        assert_eq!(got[i].to_bits(), want.to_bits(), "lane {i}: {}", x[i]);
    }
}

#[test]
fn adaptive_streak_threads_across_executions() {
    // Drive the same lanes through repeated executions and check the unit
    // narrows after the 32-streak, exactly like the rust state machine.
    let Some(mut rt) = runtime_or_skip() else { return };
    let n = rt.manifest.elemwise_n;
    let exe = rt.load("r2f2_mul_adaptive").unwrap();
    let a = vec![1.1f32; n];
    let b = vec![0.9f32; n];
    let mut k = vec![2i32; n];
    let mut s = vec![0i32; n];
    let mut narrowed_at = None;
    for iter in 0..40 {
        let outs = exe
            .run(&[
                Runtime::lit_f32(&a),
                Runtime::lit_f32(&b),
                Runtime::lit_i32(&k),
                Runtime::lit_i32(&s),
            ])
            .unwrap();
        k = outs[1].to_vec().unwrap();
        s = outs[2].to_vec().unwrap();
        let narrow: Vec<i32> = outs[4].to_vec().unwrap();
        if narrow[0] == 1 && narrowed_at.is_none() {
            narrowed_at = Some(iter);
        }
    }
    assert_eq!(narrowed_at, Some(31), "narrowing must fire exactly at the streak threshold");
    assert_eq!(k[0], 1);
}
