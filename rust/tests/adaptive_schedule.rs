//! The adaptive precision scheduler's cross-layer contract
//! (DESIGN.md §10):
//!
//! 1. **Bit-exactness across engines and switch points** — a scalar
//!    adaptive run and a batched (carrier or packed) adaptive run produce
//!    the same switch schedule and bit-identical fields, counters and
//!    snapshots, including runs with ≥ 1 widen (epoch retry) and ≥ 1
//!    narrow event; a recorded decision log replays identically.
//! 2. **The accuracy/cost envelope** — on the paper's heat setup the
//!    adaptive schedule matches the all-E5M10 final RMSE within 1e-12
//!    while spending strictly less modeled datapath cost than all-E5M10
//!    (and at least the all-E4M3 floor).

use r2f2::pde::adaptive::{fixed_cost_lut, run_heat, run_heat_scalar, run_swe, run_swe_scalar};
use r2f2::pde::heat1d::{self, HeatParams};
use r2f2::pde::swe2d::{self, QuantScope, SweParams};
use r2f2::pde::{rmse, AdaptiveArith, AdaptivePolicy, BatchEngine, F64Arith, FixedArith, QuantMode};
use r2f2::softfloat::FpFormat;

/// Full-mode heat run sized so the E4M3 start widens immediately (initial
/// amplitude 500 > 480) and the decaying sine stalls in E5M10 well before
/// the end, so the ladder narrows back — ≥ 1 widen and ≥ 1 narrow.
fn heat_full_params() -> HeatParams {
    HeatParams {
        n: 17,
        dt: 0.25 / (16.0f64 * 16.0),
        steps: 900,
        snapshot_every: 100,
        ..HeatParams::default()
    }
}

fn heat_full_policy() -> AdaptivePolicy {
    let mut p = AdaptivePolicy::heat_default();
    p.epoch_len = 16;
    p
}

/// MulOnly heat run at the paper's scope: by ~step 1600 every quantized
/// product flushes below E5M10's min normal, the dynamics stall, and the
/// scheduler narrows to E4M3 for the frozen tail.
fn heat_mulonly_params() -> HeatParams {
    HeatParams { n: 33, dt: 0.25 / (32.0f64 * 32.0), steps: 3000, ..HeatParams::default() }
}

fn heat_mulonly_policy() -> AdaptivePolicy {
    let mut p = AdaptivePolicy::heat_default();
    p.epoch_len = 50;
    p
}

fn assert_fields_bit_equal(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: node {i}: {} vs {}", a[i], b[i]);
    }
}

#[test]
fn heat_full_adaptive_bit_identical_scalar_carrier_packed() {
    let p = heat_full_params();
    let pol = heat_full_policy();

    let mut s_packed = AdaptiveArith::new(pol.clone());
    let r_packed = run_heat(&p, &mut s_packed, QuantMode::Full);
    let rep = s_packed.report();
    assert!(rep.widen_events >= 1, "expected a widen: {:?}", rep.trace);
    assert!(rep.narrow_events >= 1, "expected a narrow: {:?}", rep.trace);
    assert_eq!(rep.final_format, FpFormat::E4M3);

    let mut s_scalar = AdaptiveArith::new(pol.clone());
    let r_scalar = run_heat_scalar(&p, &mut s_scalar, QuantMode::Full);
    let mut s_carrier = AdaptiveArith::new(pol).with_engine(BatchEngine::Carrier);
    let r_carrier = run_heat(&p, &mut s_carrier, QuantMode::Full);

    // Same schedule (decisions and applied switches) on every path.
    assert_eq!(s_scalar.decisions(), s_packed.decisions());
    assert_eq!(s_scalar.trace(), s_packed.trace());
    assert_eq!(s_carrier.trace(), s_packed.trace());

    // Bit-identical fields, counters and snapshots across the engines,
    // through the widen retry and the narrow repack.
    assert_fields_bit_equal(&r_scalar.u, &r_packed.u, "scalar vs packed");
    assert_fields_bit_equal(&r_scalar.u, &r_carrier.u, "scalar vs carrier");
    assert_eq!(r_scalar.muls, r_packed.muls);
    assert_eq!(r_scalar.muls, r_carrier.muls);
    assert_eq!(r_scalar.range_events, r_packed.range_events);
    assert_eq!(r_scalar.range_events, r_carrier.range_events);
    assert_eq!(r_scalar.snapshots.len(), r_packed.snapshots.len());
    for (s, (a, b)) in r_scalar.snapshots.iter().zip(r_packed.snapshots.iter()).enumerate() {
        assert_eq!(a.0, b.0, "snapshot {s} step");
        assert_fields_bit_equal(&a.1, &b.1, "snapshot fields");
    }
}

#[test]
fn heat_mulonly_adaptive_bit_identical_and_replayable() {
    let p = heat_mulonly_params();
    let pol = heat_mulonly_policy();

    let mut live = AdaptiveArith::new(pol.clone());
    let r_live = run_heat(&p, &mut live, QuantMode::MulOnly);
    let rep = live.report();
    assert!(rep.widen_events >= 1, "expected a widen: {:?}", rep.trace);
    assert!(rep.narrow_events >= 1, "expected a narrow: {:?}", rep.trace);

    // Live scalar run re-derives the same schedule from its own telemetry.
    let mut s_scalar = AdaptiveArith::new(pol.clone());
    let r_scalar = run_heat_scalar(&p, &mut s_scalar, QuantMode::MulOnly);
    assert_eq!(s_scalar.decisions(), live.decisions());
    assert_eq!(s_scalar.trace(), live.trace());
    assert_fields_bit_equal(&r_scalar.u, &r_live.u, "live scalar vs live packed");
    assert_eq!(r_scalar.muls, r_live.muls);
    assert_eq!(r_scalar.range_events, r_live.range_events);

    // Replaying the recorded decision log on the scalar path pins it to
    // the packed run's switch schedule — "same schedule" by construction.
    let mut replay = AdaptiveArith::from_trace(pol, rep.decisions.clone());
    let r_replay = run_heat_scalar(&p, &mut replay, QuantMode::MulOnly);
    assert_eq!(replay.trace(), &rep.trace[..]);
    assert_fields_bit_equal(&r_replay.u, &r_live.u, "replay vs live");
    assert_eq!(r_replay.range_events, r_live.range_events);
}

#[test]
fn heat_adaptive_matches_e5m10_rmse_at_strictly_lower_modeled_cost() {
    let p = heat_mulonly_params();
    let reference = heat1d::run(&p, &mut F64Arith, QuantMode::MulOnly);
    let mut wide_be = FixedArith::new(FpFormat::E5M10);
    let wide = heat1d::run(&p, &mut wide_be, QuantMode::MulOnly);
    let mut narrow_be = FixedArith::new(FpFormat::E4M3);
    let narrow = heat1d::run(&p, &mut narrow_be, QuantMode::MulOnly);

    let mut sched = AdaptiveArith::new(heat_mulonly_policy());
    let adaptive = heat1d::run_adaptive(&p, &mut sched, QuantMode::MulOnly);
    let rep = sched.report();
    assert!(rep.widen_events >= 1 && rep.narrow_events >= 1, "trace: {:?}", rep.trace);
    assert_eq!(rep.final_format, FpFormat::E4M3);

    // Accuracy: the widen retry discards the E4M3 attempt and the narrow
    // fires only once the dynamics stalled, so the committed trajectory is
    // the all-E5M10 one bit-for-bit — the RMSE matches within 1e-12 (here:
    // exactly).
    assert_fields_bit_equal(&adaptive.u, &wide.u, "adaptive vs all-E5M10");
    let rmse_wide = rmse(&wide.u, &reference.u);
    let rmse_adaptive = rmse(&adaptive.u, &reference.u);
    assert!(
        (rmse_adaptive - rmse_wide).abs() <= 1e-12,
        "adaptive {rmse_adaptive} vs E5M10 {rmse_wide}"
    );

    // Cost: strictly below all-E5M10 (the narrow tail outweighs the one
    // retried epoch), and no lower than the all-E4M3 floor.
    let cost_adaptive = rep.modeled_cost_lut;
    let cost_wide = fixed_cost_lut(FpFormat::E5M10, wide.muls);
    let cost_floor = fixed_cost_lut(FpFormat::E4M3, wide.muls);
    assert!(
        cost_adaptive < cost_wide,
        "adaptive cost {cost_adaptive} must beat all-E5M10 {cost_wide}"
    );
    assert!(cost_adaptive >= cost_floor, "cost {cost_adaptive} below floor {cost_floor}");

    // Envelope: adaptive error never exceeds the worst fixed rung.
    let rmse_narrow = rmse(&narrow.u, &reference.u);
    assert!(rmse_adaptive <= rmse_wide.max(rmse_narrow) + 1e-15);
}

#[test]
fn swe_adaptive_widens_on_shelf_scale_and_stays_bit_identical() {
    // 0.5·g·h² ≈ 5e6 ≫ 65504: the E5M10 start must widen to E6M9 in the
    // first epoch; the committed trajectory is then the all-E6M9 run.
    let p = SweParams { steps: 24, ..SweParams::default() };
    let pol = AdaptivePolicy::swe_default();

    let mut a = AdaptiveArith::new(pol.clone());
    let ra = run_swe(&p, &mut a, QuantScope::UxFluxOnly, QuantMode::MulOnly);
    let rep = a.report();
    assert!(rep.widen_events >= 1, "trace: {:?}", rep.trace);
    assert_eq!(rep.final_format, FpFormat::new(6, 9));

    let mut b = AdaptiveArith::new(pol);
    let rb = run_swe_scalar(&p, &mut b, QuantScope::UxFluxOnly, QuantMode::MulOnly);
    assert_eq!(a.trace(), b.trace());
    assert_fields_bit_equal(&ra.h, &rb.h, "h");
    assert_fields_bit_equal(&ra.u, &rb.u, "u");
    assert_fields_bit_equal(&ra.v, &rb.v, "v");
    assert_eq!(ra.muls, rb.muls);
    assert_eq!(ra.range_events, rb.range_events);
    assert_eq!(ra.mass_drift.to_bits(), rb.mass_drift.to_bits());

    // The retried first epoch restores the pristine grid, so the committed
    // fields equal the all-E6M9 fixed run exactly.
    let mut fixed = FixedArith::new(FpFormat::new(6, 9));
    let rf = swe2d::run(&p, &mut fixed, QuantScope::UxFluxOnly);
    assert_fields_bit_equal(&ra.h, &rf.h, "adaptive vs all-E6M9 h");
    assert_fields_bit_equal(&ra.u, &rf.u, "adaptive vs all-E6M9 u");
}
