//! Keep-alive edge-case suite (DESIGN.md §16): the acceptor/worker
//! division of labor under connection reuse, pipelining, half-closes and
//! silent clients.
//!
//! The §12 server burned a worker thread per connection for its whole
//! lifetime; the §16 acceptor owns every idle socket and a worker is only
//! charged while request bytes are actually being answered. These tests
//! pin the edges of that contract: pipelined requests answered in order
//! on one socket, half-closed sockets reaped by the acceptor (not a
//! worker), silent connections expired at the keep-alive deadline, a
//! mid-header staller bounded by the per-request read deadline, and
//! keep-alive responses byte-identical to one-shot ones modulo the
//! `connection:` header.

use r2f2::config::{parse_json, ExperimentConfig};
use r2f2::coordinator::run_experiment;
use r2f2::metrics::Registry;
use r2f2::server::{http, outcome_json, ServeOptions, Server};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn start(keepalive_ms: u64) -> Server {
    Server::start(ServeOptions {
        port: 0,
        workers: 2,
        queue_cap: 8,
        cache_cap: 8,
        keepalive_ms,
        jobs_cap: 8,
    })
    .expect("server binds port 0")
}

fn expected_response(body: &str) -> String {
    let cfg = ExperimentConfig::from_json(&parse_json(body).unwrap()).unwrap();
    outcome_json(&run_experiment(&cfg, &Registry::new()))
}

/// Poll the metrics rollup until `counter` reaches `want` (bounded).
fn await_counter(server: &Server, counter: &str, want: u64) {
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        if server.metrics_snapshot().counter(counter) >= want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{counter} never reached {want} (at {})",
            server.metrics_snapshot().counter(counter)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn pipelined_requests_are_answered_in_order_on_one_socket() {
    let server = start(5000);
    let addr = server.addr();
    let run_a = r#"{"app": "heat", "backend": "fixed:E5M10",
                    "heat": {"n": 17, "dt": 0.0009765625, "steps": 10}}"#;
    let run_b = r#"{"app": "heat", "backend": "f32",
                    "heat": {"n": 17, "dt": 0.0009765625, "steps": 10}}"#;

    // Queue three requests before reading any response; HTTP/1.1 requires
    // in-order answers, and distinct bodies prove the order is real.
    let mut c = http::Client::connect(addr).unwrap();
    c.send_only("POST", "/v1/run", run_a.as_bytes(), false).unwrap();
    c.send_only("POST", "/v1/run", run_b.as_bytes(), false).unwrap();
    c.send_only("GET", "/healthz", b"", false).unwrap();

    let ra = c.recv().unwrap();
    assert_eq!(ra.status, 200);
    assert_eq!(ra.text(), expected_response(run_a), "first answer is the first request's");
    let rb = c.recv().unwrap();
    assert_eq!(rb.status, 200);
    assert_eq!(rb.text(), expected_response(run_b), "second answer is the second request's");
    let rh = c.recv().unwrap();
    assert_eq!(rh.status, 200);
    assert!(rh.text().contains("\"status\": \"ok\""));
    for r in [&ra, &rb, &rh] {
        assert_eq!(r.header("connection"), Some("keep-alive"));
    }

    // All three rode one TCP connection, whichever mix of same-worker
    // pipelining and acceptor re-dispatch carried them.
    let snap = server.metrics_snapshot();
    assert_eq!(snap.counter("serve.accepted"), 1, "one connection for three requests");
    assert!(
        snap.counter("serve.pipelined")
            + snap.counter("serve.keepalive.reuses")
            + snap.counter("serve.keepalive.parked")
            >= 2,
        "reuse must be visible in the metrics"
    );
    server.shutdown();
}

#[test]
fn half_closed_sockets_are_reaped_by_the_acceptor() {
    let server = start(5000);
    let mut s = TcpStream::connect(server.addr()).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    // The acceptor's peek sees EOF — no worker is charged, no deadline
    // needs to pass.
    await_counter(&server, "serve.closed", 1);
    // The server closed its side too: the read half drains to EOF.
    s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
    let mut sink = Vec::new();
    assert_eq!(s.read_to_end(&mut sink).unwrap_or(0), 0, "no bytes for a dead connection");
    server.shutdown();
}

#[test]
fn idle_connections_expire_at_the_keepalive_deadline() {
    let server = start(50); // 50 ms keep-alive window
    let mut s = TcpStream::connect(server.addr()).unwrap();
    // Send nothing: the connection sits in the acceptor's idle table and
    // must be expired by the deadline sweep, costing no worker.
    await_counter(&server, "serve.idle_expired", 1);
    s.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
    let mut sink = Vec::new();
    assert_eq!(s.read_to_end(&mut sink).unwrap_or(0), 0, "expired socket is closed");

    // A served-then-silent connection expires the same way.
    let mut c = http::Client::connect(server.addr()).unwrap();
    let r = c.send("GET", "/healthz", b"").unwrap();
    assert_eq!(r.status, 200);
    await_counter(&server, "serve.idle_expired", 2);
    server.shutdown();
}

#[test]
fn a_mid_header_staller_is_bounded_by_the_read_deadline() {
    let server = start(5000);
    let mut s = TcpStream::connect(server.addr()).unwrap();
    // Dribble half a request line and stall. The first bytes wake the
    // acceptor and charge a worker — whose 2-second read deadline then
    // bounds the damage: a 400, not a captured thread.
    s.write_all(b"GET /heal").unwrap();
    s.flush().unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let t0 = Instant::now();
    let resp = http::read_response(&mut std::io::BufReader::new(&s));
    let waited = t0.elapsed();
    match resp {
        Ok(r) => assert_eq!(r.status, 400, "stalled request must be answered 400"),
        Err(_) => {} // server may also just close after the deadline
    }
    assert!(
        waited < Duration::from_secs(8),
        "the staller must be cut off by the read deadline, waited {waited:?}"
    );
    // The worker survived; the server still answers.
    let r = http::request(server.addr(), "GET", "/healthz", b"").unwrap();
    assert_eq!(r.status, 200);
    server.shutdown();
}

#[test]
fn keep_alive_responses_are_byte_identical_to_one_shot() {
    let server = start(5000);
    let addr = server.addr();
    let body = r#"{"app": "heat", "backend": "fixed:E5M10",
                   "heat": {"n": 17, "dt": 0.0009765625, "steps": 10}}"#;

    let one_shot = http::request(addr, "POST", "/v1/run", body.as_bytes()).unwrap();
    assert_eq!(one_shot.status, 200);
    assert_eq!(one_shot.header("connection"), Some("close"));

    let mut c = http::Client::connect(addr).unwrap();
    let kept = c.send("POST", "/v1/run", body.as_bytes()).unwrap();
    assert_eq!(kept.status, 200);
    assert_eq!(kept.header("connection"), Some("keep-alive"));
    assert_eq!(
        kept.body, one_shot.body,
        "the payload must not depend on the connection's disposition"
    );
    // Same again on the same socket (a cache hit now): still identical.
    let again = c.send("POST", "/v1/run", body.as_bytes()).unwrap();
    assert_eq!(again.header("x-r2f2-cache"), Some("hit"));
    assert_eq!(again.body, one_shot.body);
    server.shutdown();
}

#[test]
fn connection_close_is_honored_mid_keep_alive() {
    let server = start(5000);
    let mut c = http::Client::connect(server.addr()).unwrap();
    let r = c.send("GET", "/healthz", b"").unwrap();
    assert_eq!(r.header("connection"), Some("keep-alive"));
    c.send_only("GET", "/healthz", b"", true).unwrap();
    let r = c.recv().unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.header("connection"), Some("close"), "the close request is the last answered");
    assert!(c.recv().is_err(), "the server must close after honoring connection: close");
    server.shutdown();
}
