//! Serving-loop contract suite (DESIGN.md §12).
//!
//! The serving layer adds three moving parts on top of the solvers — a
//! worker pool, a job queue and a result cache — and this suite pins the
//! property that makes the whole layer sound: **a served response is
//! byte-identical to a direct `run_experiment` of the same config**, no
//! matter which worker ran it, whether it was a cache hit or a miss, or
//! how many clients were racing. Plus the operational contracts: cache
//! hits actually happen on repeats, a full 1-slot queue rejects with 503
//! while in-flight work still completes, and shutdown joins every thread
//! and releases the port.

use r2f2::config::{parse_json, ExperimentConfig};
use r2f2::coordinator::run_experiment;
use r2f2::metrics::Registry;
use r2f2::server::{http, outcome_json, ServeOptions, Server};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn start(workers: usize, queue_cap: usize, cache_cap: usize) -> Server {
    Server::start(ServeOptions {
        port: 0,
        workers,
        queue_cap,
        cache_cap,
        keepalive_ms: 5000,
        jobs_cap: 8,
    })
    .expect("server binds port 0")
}

/// One small config per registry scenario (mixed backends and modes).
fn mixed_bodies() -> Vec<String> {
    vec![
        r#"{"app": "heat", "backend": "fixed:E5M10",
            "heat": {"n": 33, "dt": 0.000244140625, "steps": 40}}"#
            .to_string(),
        r#"{"app": "advection", "backend": "fixed:E5M10",
            "advection": {"n": 64, "steps": 50}}"#
            .to_string(),
        r#"{"app": "wave", "backend": "fixed:E5M10", "mode": "full",
            "wave": {"n": 17, "steps": 40}}"#
            .to_string(),
        r#"{"app": "swe", "backend": "r2f2:<3,8,4>", "swe": {"steps": 5}}"#.to_string(),
    ]
}

/// What the server must answer for `body`, computed directly — same JSON
/// lowering, same job runner, same serializer.
fn expected_response(body: &str) -> String {
    let cfg = ExperimentConfig::from_json(&parse_json(body).unwrap()).unwrap();
    outcome_json(&run_experiment(&cfg, &Registry::new()))
}

#[test]
fn concurrent_mixed_load_is_bit_identical_to_direct_runs() {
    let server = start(4, 32, 64);
    let addr = server.addr();
    let bodies = Arc::new(mixed_bodies());

    // ≥ 8 concurrent clients, 4 requests each, cycling the scenario mix —
    // every body is requested 8 times, so repeats (and cache hits, and in
    // debug the determinism guard) are guaranteed.
    let handles: Vec<_> = (0..8)
        .map(|c| {
            let bodies = bodies.clone();
            std::thread::spawn(move || {
                let mut got: Vec<(usize, String, String)> = Vec::new();
                for i in 0..4 {
                    let which = (c + i) % bodies.len();
                    let resp = http::request(addr, "POST", "/v1/run", bodies[which].as_bytes())
                        .expect("request succeeds");
                    assert_eq!(resp.status, 200, "{}", resp.text());
                    let cache = resp.header("x-r2f2-cache").expect("cache header").to_string();
                    assert!(resp.header("x-r2f2-key").is_some(), "content address header");
                    got.push((which, cache, resp.text()));
                }
                got
            })
        })
        .collect();
    let mut responses: Vec<(usize, String, String)> = Vec::new();
    for h in handles {
        responses.extend(h.join().unwrap());
    }
    assert_eq!(responses.len(), 32);

    // Every response — hit or miss, any worker — bit-equals the direct run.
    let expected: Vec<String> = bodies.iter().map(|b| expected_response(b)).collect();
    for (which, _, text) in &responses {
        assert_eq!(
            text, &expected[*which],
            "served response diverged from direct run_experiment (config {which})"
        );
    }

    // Repeats must have produced hits, and the counters must agree.
    let hits = responses.iter().filter(|(_, c, _)| c == "hit").count();
    assert!(hits > 0, "32 requests over 4 configs must produce cache hits");
    let stats = server.cache_stats();
    assert_eq!(stats.hits as usize, hits);
    assert!(stats.misses >= bodies.len() as u64, "each config misses at least once");
    #[cfg(debug_assertions)]
    assert!(stats.guard_checks > 0, "sampled hits re-verify determinism in debug");

    // The /metrics rollup sees the same world.
    let resp = http::request(addr, "GET", "/metrics", b"").unwrap();
    assert_eq!(resp.status, 200);
    let m = parse_json(&resp.text()).expect("metrics endpoint emits well-formed JSON");
    let counters = m.get("counters").expect("counters section");
    assert_eq!(
        counters.get("serve.cache.hits").and_then(|v| v.as_f64()),
        Some(hits as f64),
        "cache-hit counter advances on repeated configs"
    );
    let snapshot = server.metrics_snapshot();
    assert_eq!(snapshot.counter("serve.cache.hits"), hits as u64);
    // `serve.served` increments after the response is written, so poll
    // briefly instead of racing the last worker's bookkeeping.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        let served = server.metrics_snapshot().counter("serve.served");
        if served >= 33 {
            break; // 32 runs + the /metrics request
        }
        assert!(std::time::Instant::now() < deadline, "served stuck at {served}");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(snapshot.percentiles("serve.handle_ns", &[0.5, 0.99]).is_some());

    server.shutdown();
}

#[test]
fn textually_different_bodies_share_one_content_address() {
    let server = start(2, 8, 8);
    let addr = server.addr();
    // Same config, different key order and whitespace.
    let a = r#"{"app": "heat", "backend": "fixed:E5M10",
                "heat": {"n": 17, "dt": 0.0009765625, "steps": 10}}"#;
    let b = r#"{ "heat": {"steps": 10, "n": 17, "dt": 0.0009765625},
                 "backend": "fixed:E5M10", "app": "heat" }"#;
    let ra = http::request(addr, "POST", "/v1/run", a.as_bytes()).unwrap();
    let rb = http::request(addr, "POST", "/v1/run", b.as_bytes()).unwrap();
    assert_eq!(ra.status, 200);
    assert_eq!(rb.status, 200);
    assert_eq!(ra.header("x-r2f2-cache"), Some("miss"));
    assert_eq!(rb.header("x-r2f2-cache"), Some("hit"), "content addressing, not text addressing");
    assert_eq!(ra.header("x-r2f2-key"), rb.header("x-r2f2-key"));
    assert_eq!(ra.text(), rb.text());
    server.shutdown();
}

#[test]
fn scenarios_healthz_and_error_routes() {
    let server = start(2, 8, 8);
    let addr = server.addr();

    let resp = http::request(addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(
        parse_json(&resp.text()).unwrap().get("status").unwrap().as_str(),
        Some("ok")
    );

    let resp = http::request(addr, "GET", "/v1/scenarios", b"").unwrap();
    assert_eq!(resp.status, 200);
    let j = parse_json(&resp.text()).unwrap();
    let names: Vec<&str> = j
        .get("scenarios")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("name").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(names, vec!["heat1d", "swe2d", "advection1d", "wave2d"]);

    // Error paths answer JSON errors, never hang, never kill a worker.
    let resp = http::request(addr, "POST", "/v1/run", b"{not json").unwrap();
    assert_eq!(resp.status, 400);
    let resp = http::request(addr, "POST", "/v1/run", b"{\"app\": \"chess\"}").unwrap();
    assert_eq!(resp.status, 400);
    let resp = http::request(addr, "GET", "/v1/run", b"").unwrap();
    assert_eq!(resp.status, 405);
    let resp = http::request(addr, "POST", "/healthz", b"").unwrap();
    assert_eq!(resp.status, 405);
    let resp = http::request(addr, "GET", "/no/such/route", b"").unwrap();
    assert_eq!(resp.status, 404);

    // The server is still fully alive afterwards.
    let resp = http::request(addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(resp.status, 200);
    server.shutdown();
}

#[test]
fn sharded_responses_are_byte_identical_to_unsharded() {
    // `shards` (pde::decomp, DESIGN.md §13) must be invisible in the
    // served bytes: same config at shards=1 and shards=4 answers the
    // identical body, and both bit-equal the direct run.
    let server = start(4, 8, 8);
    let addr = server.addr();
    let base = r#"{"app": "heat", "backend": "fixed:E5M10", "shards": 1,
                   "heat": {"n": 33, "dt": 0.000244140625, "steps": 40}}"#;
    let sharded = base.replace("\"shards\": 1", "\"shards\": 4");

    let r1 = http::request(addr, "POST", "/v1/run", base.as_bytes()).unwrap();
    let r4 = http::request(addr, "POST", "/v1/run", sharded.as_bytes()).unwrap();
    assert_eq!(r1.status, 200, "{}", r1.text());
    assert_eq!(r4.status, 200, "{}", r4.text());
    // Different shard counts are different content addresses — the second
    // request must be a genuine sharded run, not a cache hit on the first.
    assert_eq!(r4.header("x-r2f2-cache"), Some("miss"));
    assert_ne!(r1.header("x-r2f2-key"), r4.header("x-r2f2-key"));
    assert_eq!(r1.text(), r4.text(), "shards=4 response diverged from shards=1");
    assert_eq!(r1.text(), expected_response(base));
    server.shutdown();
}

#[test]
fn serving_limits_scale_with_shards() {
    // A grid 4× over the unsharded 10⁶-node cap: rejected with 400 as-is,
    // admitted — and actually served — once `shards` spreads each step
    // across that many pool workers. dt = 3e-14 keeps r = dt/dx² = 0.48
    // under the explicit-scheme stability bound at n = 4_000_001.
    let server = start(2, 8, 8);
    let addr = server.addr();
    let over = r#"{"app": "heat", "backend": "f64",
                   "heat": {"n": 4000001, "dt": 3e-14, "steps": 1}}"#;
    let resp = http::request(addr, "POST", "/v1/run", over.as_bytes()).unwrap();
    assert_eq!(resp.status, 400, "over-limit unsharded config must be rejected");

    let sharded = r#"{"app": "heat", "backend": "f64", "shards": 4,
                      "heat": {"n": 4000001, "dt": 3e-14, "steps": 1}}"#;
    let resp = http::request(addr, "POST", "/v1/run", sharded.as_bytes()).unwrap();
    assert_eq!(resp.status, 200, "sharded equivalent must be admitted");
    // The body echoes a 4-million-node field — spot-check it rather than
    // re-parsing ~80 MB of JSON.
    let text = resp.text();
    assert!(text.contains("\"n\": 4000001"), "served field must be the full grid");
    assert!(text.contains("\"rel_err_vs_f64\": 0,"), "f64 run matches its own reference");
    server.shutdown();
}

#[test]
fn job_submission_enforces_the_same_serving_limits_as_v1_run() {
    // Regression: the async job layer must reject a hostile config at
    // POST /v1/jobs time (400, nothing enqueued), not at execution time —
    // an admitted 4-million-node job would otherwise tie up a worker
    // allocating ~10⁸ bytes before the limit check fired. Same grid as
    // the /v1/run case above; the sharded variant is legitimate and must
    // still be admitted asynchronously (202).
    let server = start(2, 8, 8);
    let addr = server.addr();
    let over = r#"{"app": "heat", "backend": "f64",
                   "heat": {"n": 4000001, "dt": 3e-14, "steps": 1}}"#;
    let resp = http::request(addr, "POST", "/v1/jobs", over.as_bytes()).unwrap();
    assert_eq!(resp.status, 400, "over-limit job must be rejected at submit time");
    assert!(resp.text().contains("serving limit"), "{}", resp.text());

    // Nothing was enqueued: the store reports zero live jobs.
    let m = http::request(addr, "GET", "/metrics", b"").unwrap();
    let j = parse_json(&m.text()).unwrap();
    let live = j.get("gauges").and_then(|g| g.get("serve.jobs.live")).and_then(|v| v.as_f64());
    assert_eq!(live, Some(0.0), "rejected job must not occupy a store slot");

    let sharded = r#"{"app": "heat", "backend": "f64", "shards": 4,
                      "heat": {"n": 4000001, "dt": 3e-14, "steps": 1}}"#;
    let resp = http::request(addr, "POST", "/v1/jobs", sharded.as_bytes()).unwrap();
    assert_eq!(resp.status, 202, "sharded equivalent must be admitted: {}", resp.text());
    server.shutdown();
}

#[test]
fn one_slot_queue_rejects_excess_load_with_503() {
    // 1 worker, 1 queue slot: once a slow request occupies the worker and
    // a second occupies the slot, further requests must be rejected.
    let server = start(1, 1, 8);
    let addr = server.addr();
    // Slow enough to hold the worker for the whole burst in any profile:
    // ~1.5 M quantized muls plus the f64 reference run (tens of ms in
    // release, ~a second in debug — the barrier-released burst lands in
    // well under either).
    let slow = r#"{"app": "heat", "backend": "fixed:E5M10",
                   "heat": {"n": 129, "dt": 0.0000152587890625, "steps": 4000}}"#;

    let barrier = Arc::new(std::sync::Barrier::new(8));
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                barrier.wait();
                http::request(addr, "POST", "/v1/run", slow.as_bytes())
                    .map(|r| r.status)
                    .unwrap_or(0)
            })
        })
        .collect();
    let statuses: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let ok = statuses.iter().filter(|&&s| s == 200).count();
    let rejected = statuses.iter().filter(|&&s| s == 503).count();
    assert_eq!(ok + rejected, 8, "every client gets a definite answer: {statuses:?}");
    assert!(ok >= 1, "admitted work completes: {statuses:?}");
    assert!(rejected >= 1, "a saturated 1-slot queue must reject: {statuses:?}");

    let snapshot = server.metrics_snapshot();
    assert_eq!(snapshot.counter("serve.rejected"), rejected as u64);
    // All 8 requests hold the same config, so the rejected ones lost
    // nothing: the survivors' responses are cache-consistent.
    assert_eq!(server.cache_stats().misses, 1);
    server.shutdown();
}

#[test]
fn graceful_shutdown_joins_workers_and_releases_the_port() {
    let server = start(3, 8, 8);
    let addr = server.addr();
    let resp = http::request(addr, "GET", "/healthz", b"").unwrap();
    assert_eq!(resp.status, 200);

    // shutdown() blocks until the acceptor and every worker have been
    // joined — returning *is* the no-leaked-threads assertion.
    server.shutdown();

    // The listener is gone: connects are refused (give the OS a moment).
    std::thread::sleep(Duration::from_millis(50));
    assert!(
        TcpStream::connect(addr).is_err(),
        "port must be released after shutdown"
    );
}
