//! Conformance suite for the static-analysis pass (DESIGN.md §15).
//!
//! Fixture corpus: every shipped rule is demonstrated (a) firing on a
//! minimal violation, (b) staying silent on the policy-allowlisted idiom,
//! (c) ignoring matches hidden in comments and string literals, and
//! (d) suppressed by a reasoned allow marker — with reason-less, unknown
//! and malformed markers producing `allow-marker` findings. The final
//! tests run the auditor end-to-end over the real tree and pin the
//! committed `AUDIT_smoke.json` snapshot.
//!
//! All fixtures live in raw strings, so the auditor's own scan of this
//! file sees only blanked literals — the suite can exercise violations
//! without carrying any.

use r2f2::audit::{audit_cargo_toml, audit_source, find_root, run, Options, AuditReport, RULES};

/// Rule ids found (unsuppressed) in a fixture.
fn fired(rep: &AuditReport) -> Vec<&str> {
    rep.findings.iter().map(|f| f.rule.as_str()).collect()
}

#[test]
fn native_float_fires_in_kernel_modules() {
    for path in [
        "rust/src/softfloat/mul.rs",
        "rust/src/softfloat/add.rs",
        "rust/src/softfloat/round.rs",
        "rust/src/softfloat/packed.rs",
        "rust/src/softfloat/swar.rs",
    ] {
        let rep = audit_source(path, r#"pub fn leak(x: f64) -> f64 { x * 2.0 }"#);
        assert_eq!(fired(&rep), vec!["native-float-quarantine"], "{path}");
        assert_eq!(rep.findings[0].line, 1);
        assert!(rep.findings[0].snippet.contains("leak"), "finding quotes the line");
    }
    // f32 and literal suffixes count too; many hits on a line dedupe.
    let rep = audit_source(
        "rust/src/softfloat/swar.rs",
        r#"fn f(a: f32) -> f64 { a as f64 + 2.0f64 }"#,
    );
    assert_eq!(rep.findings.len(), 1, "one finding per (line, rule)");
}

#[test]
fn native_float_silent_outside_quarantine_and_on_identifiers() {
    // The f64 reference solvers and the carrier boundary are policy, not
    // marker, exemptions.
    for path in
        ["rust/src/pde/heat1d.rs", "rust/src/softfloat/encode.rs", "rust/src/analysis/mod.rs"]
    {
        let rep = audit_source(path, r#"pub fn reference(x: f64) -> f64 { x }"#);
        assert!(rep.findings.is_empty(), "{path} is outside the quarantine");
    }
    // Identifiers and constants that merely *contain* the token.
    let rep = audit_source(
        "rust/src/softfloat/packed.rs",
        r#"let e_f64 = (exp + bias) as u64; const F64_EXP_MASK: u64 = 0x7ff;"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn comment_and_string_matches_are_ignored() {
    let rep = audit_source(
        "rust/src/softfloat/mul.rs",
        r#"// widens to f64 conceptually, but the datapath is u64
let label = "f64 carrier"; /* also f64 here */
let raw = r"f32 and f64 in a raw string";"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);

    let rep = audit_source(
        "rust/src/server/mod.rs",
        r#"let doc = "call Instant::now for wall time"; // Instant::now in prose"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn wall_clock_fires_on_result_paths_only() {
    let bad = r#"let t0 = std::time::Instant::now();"#;
    let rep = audit_source("rust/src/server/mod.rs", bad);
    assert_eq!(fired(&rep), vec!["wall-clock-quarantine"]);
    let rep = audit_source("rust/src/pde/mod.rs", r#"let t = SystemTime::now();"#);
    assert_eq!(fired(&rep), vec!["wall-clock-quarantine"]);

    // metrics/ and the bench harness are the sanctioned homes of the clock.
    for path in ["rust/src/metrics/mod.rs", "rust/src/bench_util.rs"] {
        let rep = audit_source(path, bad);
        assert!(rep.findings.is_empty(), "{path} is policy-allowlisted");
    }
    // Benches measure time by design — outside the rule's include set.
    let rep = audit_source("rust/benches/fig8_swe.rs", bad);
    assert!(rep.findings.is_empty());
}

#[test]
fn ordered_iteration_fires_in_result_affecting_modules() {
    let bad = r#"use std::collections::HashMap; let m: HashMap<u32, u32> = HashMap::new();"#;
    for path in [
        "rust/src/config/mod.rs",
        "rust/src/sweep/error_sweep.rs",
        "rust/src/pde/scenario.rs",
        "rust/src/softfloat/batch.rs",
    ] {
        let rep = audit_source(path, bad);
        assert_eq!(fired(&rep), vec!["ordered-iteration"], "{path}");
    }
    let rep = audit_source("rust/src/server/cache.rs", bad);
    assert!(rep.findings.is_empty(), "server is outside the ordered-iteration policy");
    let rep = audit_source("rust/src/config/mod.rs", r#"let s: HashSet<u32> = HashSet::new();"#);
    assert_eq!(fired(&rep), vec!["ordered-iteration"]);
}

#[test]
fn rng_discipline_catches_entropy_and_inline_mixers() {
    let rep = audit_source("rust/src/pde/mod.rs", r#"let mut rng = thread_rng();"#);
    assert_eq!(fired(&rep), vec!["rng-discipline"]);
    let rep = audit_source(
        "rust/src/sweep/mod.rs",
        r#"let s = std::collections::hash_map::RandomState::new();"#,
    );
    assert_eq!(fired(&rep), vec!["rng-discipline"]);
    // An inline SplitMix64 mixer, grouped and upper-cased — the Const
    // patterns normalize before matching.
    let rep = audit_source(
        "rust/src/pde/adaptive.rs",
        r#"state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);"#,
    );
    assert_eq!(fired(&rep), vec!["rng-discipline"]);
    // An inline LCG multiplier (the PCG/Knuth constant).
    let rep = audit_source(
        "rust/src/analysis/mod.rs",
        r#"seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);"#,
    );
    assert_eq!(fired(&rep), vec!["rng-discipline"]);
    // rng.rs itself is the sanctioned home of those constants.
    let rep = audit_source(
        "rust/src/rng.rs",
        r#"self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);"#,
    );
    assert!(rep.findings.is_empty());
}

#[test]
fn unsafe_free_fires_everywhere_including_tests() {
    let bad = r#"pub fn hole(p: *const u8) -> u8 { unsafe { *p } }"#;
    for path in [
        "rust/src/softfloat/mod.rs",
        "rust/benches/hotpath.rs",
        "rust/tests/decomp_identity.rs",
        "examples/quickstart.rs",
    ] {
        let rep = audit_source(path, bad);
        assert_eq!(fired(&rep), vec!["unsafe-free"], "{path}");
    }
    // NOT test-exempt: an unsafe block inside #[cfg(test)] still fires.
    let rep = audit_source(
        "rust/src/pde/mod.rs",
        r#"pub fn ok() {}
#[cfg(test)]
mod tests {
    fn hole(p: *const u8) -> u8 { unsafe { *p } }
}"#,
    );
    assert_eq!(fired(&rep), vec!["unsafe-free"]);
    assert_eq!(rep.findings[0].line, 4);
    // `unsafe_code` (the forbid attribute's token) is an identifier, not
    // a use of the keyword.
    let rep = audit_source("rust/src/pde/mod.rs", r#"let unsafe_code_mentions = 3;"#);
    assert!(rep.findings.is_empty());
}

#[test]
fn test_region_exempts_only_rules_that_opt_in() {
    let rep = audit_source(
        "rust/src/softfloat/mul.rs",
        r#"pub fn kernel(w: u64) -> u64 { w }
#[cfg(test)]
mod tests {
    fn oracle(x: f64) -> f64 { x }
    fn clocked() { let t = std::time::Instant::now(); let _ = t; }
}"#,
    );
    assert!(rep.findings.is_empty(), "f64 oracles and clocks in tests are fine: {:?}", rep.findings);
}

#[test]
fn trailing_marker_suppresses_and_records_allow() {
    let rep = audit_source(
        "rust/src/softfloat/packed.rs",
        r#"pub fn decode(w: u32) -> f64 { // r2f2-audit: allow(native-float-quarantine) — decode boundary, exact bits
    f64::from_bits(w as u64) // r2f2-audit: allow(native-float-quarantine) — from_bits is exact
}"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(rep.allows.len(), 2);
    assert_eq!(rep.allows[0].rule, "native-float-quarantine");
    assert_eq!(rep.allows[0].reason, "decode boundary, exact bits");
    assert!(rep.unused.is_empty());
}

#[test]
fn line_above_marker_covers_next_code_line() {
    let rep = audit_source(
        "rust/src/server/mod.rs",
        r#"// r2f2-audit: allow(wall-clock-quarantine) — connection idle timeout, not a result
let t0 = std::time::Instant::now();"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(rep.allows.len(), 1);
    assert_eq!(rep.allows[0].line, 2, "the allow is recorded at the covered line");
}

#[test]
fn marker_does_not_leak_past_its_line() {
    // The marker covers line 1 only; the same violation on line 2 fires.
    let rep = audit_source(
        "rust/src/server/mod.rs",
        r#"let a = std::time::Instant::now(); // r2f2-audit: allow(wall-clock-quarantine) — first one only
let b = std::time::Instant::now();"#,
    );
    assert_eq!(fired(&rep), vec!["wall-clock-quarantine"]);
    assert_eq!(rep.findings[0].line, 2);
    assert_eq!(rep.allows.len(), 1);
}

#[test]
fn reasonless_marker_is_flagged_but_suppression_still_applies() {
    let rep = audit_source(
        "rust/src/softfloat/mul.rs",
        r#"fn leak(x: f64) -> f64 { x } // r2f2-audit: allow(native-float-quarantine)"#,
    );
    assert_eq!(fired(&rep), vec!["allow-marker"], "the missing reason is the finding");
    assert!(rep.findings[0].note.contains("missing reason"));
    assert_eq!(rep.allows.len(), 1, "the target violation shows as allowed, not hidden");
}

#[test]
fn unknown_and_malformed_markers_are_findings_without_suppression() {
    let rep = audit_source(
        "rust/src/pde/mod.rs",
        r#"fn ok() {} // r2f2-audit: allow(no-such-rule) — whatever"#,
    );
    assert_eq!(fired(&rep), vec!["allow-marker"]);
    assert!(rep.findings[0].note.contains("unknown rule"));

    let rep = audit_source(
        "rust/src/softfloat/mul.rs",
        r#"fn leak(x: f64) -> f64 { x } // r2f2-audit: allowing this one"#,
    );
    // Malformed marker AND the (unsuppressed) violation both surface.
    let mut rules = fired(&rep);
    rules.sort_unstable();
    assert_eq!(rules, vec!["allow-marker", "native-float-quarantine"]);

    // An allow marker cannot allow itself.
    let rep = audit_source(
        "rust/src/pde/mod.rs",
        r#"fn ok() {} // r2f2-audit: allow(allow-marker) — nice try"#,
    );
    assert_eq!(fired(&rep), vec!["allow-marker"]);
    assert!(rep.findings[0].note.contains("not suppressible"));
}

#[test]
fn prose_mentions_without_the_trigger_colon_are_not_markers() {
    let rep = audit_source(
        "rust/src/pde/mod.rs",
        r#"fn ok() {} // the r2f2-audit pass would flag a HashMap here"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn unused_markers_are_surfaced_not_gating() {
    let rep = audit_source(
        "rust/src/pde/mod.rs",
        r#"fn ok() {} // r2f2-audit: allow(wall-clock-quarantine) — stale leftover"#,
    );
    assert!(rep.findings.is_empty());
    assert_eq!(rep.unused.len(), 1);
    assert!(rep.unused[0].rules.contains("wall-clock-quarantine"));
}

#[test]
fn zero_dep_fires_on_dependency_growth() {
    let rep = audit_cargo_toml(
        "rust/Cargo.toml",
        r#"[package]
name = "r2f2"

[dependencies]
serde = "1"
"#,
    );
    assert_eq!(fired(&rep), vec!["zero-dep"]);
    assert_eq!(rep.findings[0].line, 5);
    assert!(rep.findings[0].note.contains("dependencies"));

    // dev-dependencies and target-scoped sections count too.
    let rep = audit_cargo_toml("Cargo.toml", "[dev-dependencies]\nproptest = \"1\"\n");
    assert_eq!(fired(&rep), vec!["zero-dep"]);
    let rep = audit_cargo_toml(
        "rust/Cargo.toml",
        "[target.'cfg(unix)'.dependencies]\nlibc = \"0.2\"\n",
    );
    assert_eq!(fired(&rep), vec!["zero-dep"]);
}

#[test]
fn zero_dep_silent_on_features_lints_and_workspace() {
    let rep = audit_cargo_toml(
        "rust/Cargo.toml",
        r#"[package]
name = "r2f2"
edition = "2021"

[features]
default = []
pjrt = []

[lints.clippy]
type_complexity = "allow"

[[bench]]
name = "hotpath"
harness = false
"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    let rep = audit_cargo_toml("Cargo.toml", "[workspace]\nmembers = [\"rust\"]\n");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn zero_dep_suppressible_with_a_reasoned_marker() {
    let rep = audit_cargo_toml(
        "rust/Cargo.toml",
        r#"[dependencies]
# r2f2-audit: allow(zero-dep) — vendored path-only pjrt bindings, no registry fetch
xla = { path = "../xla" }
"#,
    );
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert_eq!(rep.allows.len(), 1);
    assert_eq!(rep.allows[0].rule, "zero-dep");
}

#[test]
fn rule_inventory_is_complete() {
    // The six contract rules plus the marker-hygiene rule, in the fixed
    // report order the snapshot relies on.
    let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
    assert_eq!(
        ids,
        vec![
            "native-float-quarantine",
            "wall-clock-quarantine",
            "ordered-iteration",
            "rng-discipline",
            "unsafe-free",
            "zero-dep",
            "allow-marker",
        ]
    );
    for rule in RULES {
        assert!(!rule.summary.is_empty() && !rule.contract.is_empty(), "{}", rule.id);
        assert!(rule.contract.contains('§'), "{} must cite its DESIGN.md contract", rule.id);
    }
}

// ---- end-to-end over the real tree ------------------------------------

#[test]
fn e2e_real_tree_has_zero_unsuppressed_findings() {
    let root = find_root().expect("repo root");
    let rep = run(&Options { root, rule: None }).expect("audit runs");
    assert!(rep.files_scanned > 50, "the walker saw the tree ({} files)", rep.files_scanned);
    let rendered: Vec<String> = rep
        .findings
        .iter()
        .map(|f| format!("{}:{} [{}] {} `{}`", f.file, f.line, f.rule, f.note, f.snippet))
        .collect();
    assert!(rep.findings.is_empty(), "unsuppressed findings:\n{}", rendered.join("\n"));
    // Every marker in the tree suppresses something and carries a reason.
    assert!(rep.unused.is_empty(), "stale markers: {:?}", rep.unused);
    for allow in &rep.allows {
        assert!(!allow.reason.is_empty(), "{}:{} reason-less allow", allow.file, allow.line);
    }
}

#[test]
fn e2e_snapshot_matches_committed_audit_smoke_json() {
    let root = find_root().expect("repo root");
    let committed = std::fs::read_to_string(root.join("rust/AUDIT_smoke.json"))
        .expect("rust/AUDIT_smoke.json is committed");
    let rep = run(&Options { root, rule: None }).expect("audit runs");
    let live = rep.snapshot_json("r2f2 audit");
    assert_eq!(
        live, committed,
        "allowlist population drifted — regenerate rust/AUDIT_smoke.json \
         (r2f2 audit --snapshot rust/AUDIT_smoke.json) and review the diff"
    );
}

#[test]
fn e2e_rule_filter_restricts_the_report() {
    let root = find_root().expect("repo root");
    let rep = run(&Options { root, rule: Some("native-float-quarantine".into()) })
        .expect("filtered audit runs");
    assert!(rep.findings.is_empty());
    assert!(!rep.allows.is_empty(), "the kernel boundary allows survive the filter");
    assert!(rep.allows.iter().all(|a| a.rule == "native-float-quarantine"));
}
