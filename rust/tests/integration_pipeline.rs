//! End-to-end pipeline integration: rust drives multi-step simulations
//! through the compiled AOT artifacts and checks the paper's qualitative
//! claims on the PJRT path (not just natively).
//!
//! Requires `make artifacts`; skips politely otherwise.

use r2f2::metrics::Registry;
use r2f2::runtime::{HeatRunner, Runtime, SweRunner};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::from_default_dir() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

fn sine_field(n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| 500.0 * (2.0 * std::f32::consts::PI * i as f32 / (n - 1) as f32).sin())
        .collect()
}

fn rel_l2_f32(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 =
        a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).powi(2)).sum();
    let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum();
    (num / den).sqrt()
}

#[test]
fn heat_pjrt_r2f2_matches_f32_variant() {
    // Fig 7 through the full stack: the R2F2 artifact's trajectory tracks
    // the f32 artifact's trajectory.
    let Some(mut rt) = runtime_or_skip() else { return };
    let m = Registry::new();
    let n = rt.manifest.heat_n;
    let steps = 400;
    let u0 = sine_field(n);

    let r2f2 = HeatRunner::new(&mut rt, "heat_step_r2f2", m.clone()).unwrap();
    let out_r2f2 = r2f2.run(&u0, 0.25, steps, 2).unwrap();
    let f32v = HeatRunner::new(&mut rt, "heat_step_f32", m.clone()).unwrap();
    let out_f32 = f32v.run(&u0, 0.25, steps, 0).unwrap();

    let err = rel_l2_f32(&out_r2f2.u, &out_f32.u);
    assert!(err < 5e-3, "R2F2 vs f32 on PJRT: {err}");
    // Boundary values pinned (Dirichlet) on both.
    assert_eq!(out_r2f2.u[0], u0[0]);
    assert_eq!(out_r2f2.u[n - 1], u0[n - 1]);
}

#[test]
fn heat_pjrt_adjustments_are_rare_and_counted() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let m = Registry::new();
    let n = rt.manifest.heat_n;
    let runner = HeatRunner::new(&mut rt, "heat_step_r2f2", m).unwrap();
    let out = runner.run(&sine_field(n), 0.25, 300, 2).unwrap();
    let muls = (300 * 3 * n) as i64;
    assert!(out.widen + out.narrow > 0, "some adjustment expected");
    assert!(
        out.widen + out.narrow < muls / 100,
        "adjustments must be rare: {}+{} in {muls}",
        out.widen,
        out.narrow
    );
}

#[test]
fn heat_pjrt_e5m10_freezes_small_updates() {
    // §3.1 on the PJRT path: a uniformly tiny field stops evolving under
    // E5M10 multiplications (products underflow), but not under f32.
    let Some(mut rt) = runtime_or_skip() else { return };
    let m = Registry::new();
    let n = rt.manifest.heat_n;
    // Small bump around the center, all values ≤ 1e-4. Tail values below
    // 1e-30 are clamped to zero: XLA's CPU backend runs with FTZ, so f32
    // subnormals would be flushed by the plain `u + 0` path and confound
    // the exact-freeze comparison.
    let u0: Vec<f32> = (0..n)
        .map(|i| {
            let x = (i as f32 - n as f32 / 2.0) / 20.0;
            let v = 1e-4 * (-x * x).exp();
            if v < 1e-30 {
                0.0
            } else {
                v
            }
        })
        .collect();

    let half = HeatRunner::new(&mut rt, "heat_step_e5m10", m.clone()).unwrap();
    let frozen = half.run(&u0, 0.25, 50, 0).unwrap();
    assert_eq!(frozen.u, u0, "E5M10 must freeze (all products underflow)");

    let f32v = HeatRunner::new(&mut rt, "heat_step_f32", m).unwrap();
    let alive = f32v.run(&u0, 0.25, 50, 0).unwrap();
    assert_ne!(alive.u, u0, "f32 must keep diffusing");
}

#[test]
fn swe_pjrt_r2f2_close_to_f32_and_stable() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let m = Registry::new();
    let n = rt.manifest.swe_n;
    let side = n + 2;
    // Shelf-scale drop matching python's swe_drop_init defaults.
    let mut h0 = vec![150.0f32; side * side];
    let dx = 2000.0f32;
    let sidelen = n as f32 * dx;
    let w = 0.15 * sidelen;
    for j in 0..n {
        for i in 0..n {
            let x = (i as f32 + 0.5) / n as f32 * sidelen - 0.5 * sidelen;
            let y = (j as f32 + 0.5) / n as f32 * sidelen - 0.5 * sidelen;
            // python writes h_int.T at [1+i][1+j] — row index is x.
            h0[(i + 1) * side + (j + 1)] = 150.0 + 6.0 * (-(x * x + y * y) / (w * w)).exp();
        }
    }

    let r2f2 = SweRunner::new(&mut rt, "swe_step_r2f2", m.clone()).unwrap();
    let out_r = r2f2.run(&h0, 30, 2).unwrap();
    let f32v = SweRunner::new(&mut rt, "swe_step_f32", m).unwrap();
    let out_f = f32v.run(&h0, 30, 0).unwrap();

    let err = rel_l2_f32(&out_r.h, &out_f.h);
    assert!(err < 1e-3, "R2F2 vs f32 SWE on PJRT: {err}");
    assert!(out_r.h.iter().all(|&h| h > 100.0 && h < 200.0), "depth stable");
    assert!(out_r.widen > 0, "shelf scale must force exponent widening");
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let a = rt.load("heat_step_f32").unwrap();
    let b = rt.load("heat_step_f32").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b), "second load must hit the cache");
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    for name in [
        "r2f2_mul_k2",
        "r2f2_mul_k0",
        "r2f2_mul_adaptive",
        "quantize_e5m10",
        "heat_step_r2f2",
        "heat_step_e5m10",
        "heat_step_f32",
        "swe_step_r2f2",
        "swe_step_f32",
    ] {
        assert!(rt.manifest.find(name).is_some(), "missing artifact {name}");
    }
}
