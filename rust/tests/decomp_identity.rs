//! Domain-decomposition conformance suite (DESIGN.md §13): sharding a run
//! over the worker pool (`pde::decomp`) is **bit-invisible**. For every
//! entry of `pde::scenario::SCENARIOS`, every batch engine, and both
//! quantization modes, the sharded run's final field, mul count, and range
//! telemetry counters are bit-identical to the unsharded run at any shard
//! count — including non-divisible splits and shard counts larger than the
//! grid. The adaptive scheduler derives the **same decision log** sharded
//! as unsharded, because widen-retry saves/restores all shards atomically
//! through the adapters' global save/restore.
//!
//! The CI `decomp-identity` job runs this suite under `R2F2_WORKERS` ∈
//! {1, 4} and greps the `MATRIX |` lines into the job summary — the worker
//! count must not leak into any result either.

use r2f2::analysis::Log2Histogram;
use r2f2::pde::decomp::partition;
use r2f2::pde::scenario::{ScenarioRun, ScenarioSize, SCENARIOS};
use r2f2::pde::{AdaptiveArith, BatchEngine, FixedArith, QuantMode};
use r2f2::softfloat::FpFormat;

/// Shard counts every conformance case runs at. 1 is the delegation path,
/// 2/3 include non-divisible splits for every registry grid size, 7 is
/// prime (never divides a registry grid evenly), and 61 exceeds several
/// Quick-size grids' interiors, forcing single-node slivers and the
/// shards > n clamp.
const SHARD_COUNTS: [usize; 5] = [1, 2, 3, 7, 61];

const ENGINES: [&str; 3] = ["scalar", "carrier", "packed"];

fn make_backend(engine: &str, fmt: FpFormat) -> FixedArith {
    match engine {
        "scalar" | "packed" => FixedArith::new(fmt),
        "carrier" => FixedArith::new(fmt).with_engine(BatchEngine::Carrier),
        other => panic!("unknown engine {other}"),
    }
}

fn batched(engine: &str) -> bool {
    engine != "scalar"
}

fn assert_fields_bit_equal(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: node {i}: {} vs {}", a[i], b[i]);
    }
}

fn assert_runs_bit_equal(a: &ScenarioRun, b: &ScenarioRun, what: &str) {
    assert_fields_bit_equal(&a.field, &b.field, what);
    assert_eq!(a.muls, b.muls, "{what}: muls");
    assert_eq!(a.range_events, b.range_events, "{what}: range events");
    assert_eq!(a.r2f2_stats, b.r2f2_stats, "{what}: stats");
}

/// The load-bearing matrix: scenario × engine × mode × shard count, all
/// bit-identical to the unsharded run.
#[test]
fn sharded_runs_bit_identical_for_every_scenario_engine_and_mode() {
    for spec in SCENARIOS {
        let fmt = spec.wide_format;
        for mode in [QuantMode::MulOnly, QuantMode::Full] {
            for engine in ENGINES {
                let b = batched(engine);
                let mut base_be = make_backend(engine, fmt);
                let base = (spec.run)(ScenarioSize::Quick, &mut base_be, mode, b);
                for shards in SHARD_COUNTS {
                    let mut be = make_backend(engine, fmt);
                    let run = (spec.run_sharded)(ScenarioSize::Quick, &mut be, mode, b, shards);
                    let what = format!("{}/{engine}/{mode:?}/shards={shards}", spec.name);
                    assert_runs_bit_equal(&base, &run, &what);
                }
                println!(
                    "MATRIX | {} | {engine} {:?} | shards {:?} | bit-identical |",
                    spec.name, mode, SHARD_COUNTS
                );
            }
        }
    }
}

/// The adaptive scheduler is shard-blind: decision log, switch trace,
/// range-event counters, and the committed trajectory are bit-identical at
/// every shard count, with the epoch-0 widen-retry (guaranteed by every
/// registry scenario's default setup) restoring **all** shards atomically.
#[test]
fn adaptive_schedule_and_trajectory_are_shard_invariant() {
    for spec in SCENARIOS {
        let policy = (spec.adaptive_policy)();
        let mut s_base = AdaptiveArith::new(policy.clone());
        let base = (spec.run_adaptive)(
            ScenarioSize::Adaptive,
            &mut s_base,
            QuantMode::MulOnly,
            true,
        );
        for shards in SHARD_COUNTS {
            let mut s = AdaptiveArith::new(policy.clone());
            let run = (spec.run_adaptive_sharded)(
                ScenarioSize::Adaptive,
                &mut s,
                QuantMode::MulOnly,
                true,
                shards,
            );
            let what = format!("{} adaptive shards={shards}", spec.name);
            assert_eq!(s.decisions(), s_base.decisions(), "{what}: decisions");
            assert_eq!(s.trace(), s_base.trace(), "{what}: trace");
            assert_runs_bit_equal(&base, &run, &what);
        }
        // Every registry default widens in epoch 0 (the retry is what makes
        // atomic all-shard restore load-bearing, not a vacuous pass).
        let rep = s_base.report();
        assert!(rep.widen_events >= 1, "{}: no widen exercised: {:?}", spec.name, rep.trace);
        println!(
            "MATRIX | {} | adaptive shards {:?} | schedule+field identical | widen {} narrow {} |",
            spec.name, SHARD_COUNTS, rep.widen_events, rep.narrow_events
        );
    }
}

fn assert_hist_equal(got: &Log2Histogram, want: &Log2Histogram, what: &str) {
    assert_eq!(got.total, want.total, "{what}: total");
    assert_eq!(got.zeros, want.zeros, "{what}: zeros");
    assert_eq!(got.negatives, want.negatives, "{what}: negatives");
    assert_eq!(got.nonfinite, want.nonfinite, "{what}: nonfinite");
    assert_eq!(got.nonzero_range(), want.nonzero_range(), "{what}: min/max abs");
    let a: Vec<(i32, u64)> = got.iter().collect();
    let b: Vec<(i32, u64)> = want.iter().collect();
    assert_eq!(a, b, "{what}: buckets");
}

/// Range telemetry under sharding: per-shard `Log2Histogram`s over the
/// `pde::decomp::partition` slices of the (bit-identical) sharded field,
/// merged in any order, equal the single histogram over the unsharded
/// field — counts, `nonfinite`, and the `min_abs`/`max_abs` range.
#[test]
fn per_shard_histograms_merge_to_the_unsharded_histogram() {
    for spec in SCENARIOS {
        let mut be = FixedArith::new(spec.wide_format);
        let run = (spec.run)(ScenarioSize::Quick, &mut be, QuantMode::MulOnly, true);
        let mut want = Log2Histogram::new();
        for &v in &run.field {
            want.record(v);
        }
        for shards in [2usize, 3, 7] {
            let mut be = FixedArith::new(spec.wide_format);
            let srun = (spec.run_sharded)(ScenarioSize::Quick, &mut be, QuantMode::MulOnly, true, shards);
            let per_shard: Vec<Log2Histogram> = partition(srun.field.len(), shards)
                .into_iter()
                .map(|p| {
                    let mut h = Log2Histogram::new();
                    for &v in &srun.field[p.lo..p.hi] {
                        h.record(v);
                    }
                    h
                })
                .collect();
            let mut fwd = Log2Histogram::new();
            for h in per_shard.iter() {
                fwd.merge(h);
            }
            let mut rev = Log2Histogram::new();
            for h in per_shard.iter().rev() {
                rev.merge(h);
            }
            assert_hist_equal(&fwd, &want, &format!("{} shards={shards} fwd", spec.name));
            assert_hist_equal(&rev, &want, &format!("{} shards={shards} rev", spec.name));
        }
    }

    // Fields are finite by construction above; shard a stream that also
    // carries zeros, signs, and non-finites through the same partition
    // helper so the `nonfinite` merge path is exercised under sharding too.
    let stream: Vec<f64> = vec![
        0.0,
        -0.0,
        1.5,
        f64::INFINITY,
        -2.5e-9,
        f64::NAN,
        3.0e7,
        f64::NEG_INFINITY,
        -42.0,
        0.125,
    ];
    let mut want = Log2Histogram::new();
    for &v in &stream {
        want.record(v);
    }
    for shards in [2usize, 3, 7, 10, 25] {
        let mut got = Log2Histogram::new();
        for p in partition(stream.len(), shards) {
            let mut h = Log2Histogram::new();
            for &v in &stream[p.lo..p.hi] {
                h.record(v);
            }
            got.merge(&h);
        }
        assert_hist_equal(&got, &want, &format!("nonfinite stream shards={shards}"));
    }
}
