//! The scenario registry's two transport workloads: upwind advection
//! (linear + Burgers) and the damped 2D wave equation.
//!
//! ```sh
//! cargo run --release --example advection_wave
//! ```
//!
//! Walks the new scenarios through the same precision story as the paper's
//! case studies: f64 ground truth, the fixed 16-bit formats, and the
//! adaptive FP8→half ladder driven by the generic scenario drivers.

use r2f2::pde::scenario::{find, ScenarioSize};
use r2f2::pde::{advection1d, rel_l2, AdaptiveArith, F64Arith, FixedArith, QuantMode};
use r2f2::softfloat::FpFormat;

fn main() {
    // --- 1. The registry is the source of truth for both scenarios.
    for name in ["advection1d", "wave2d"] {
        let spec = find(name).expect("registry scenario");
        println!("{name}: {}", spec.physics);
        println!("  stress: {}", spec.stress);

        let reference = (spec.run)(ScenarioSize::Accuracy, &mut F64Arith, QuantMode::MulOnly, true);
        for fmt in [FpFormat::E4M3, FpFormat::E5M10] {
            let mut be = FixedArith::new(fmt);
            let run = (spec.run)(ScenarioSize::Accuracy, &mut be, QuantMode::MulOnly, true);
            let ev = run.range_events.unwrap();
            println!(
                "  {fmt:<6} rel-err {:.3e}  overflows {}  underflows {}  ({} muls)",
                rel_l2(&run.field, &reference.field),
                ev.overflows,
                ev.underflows,
                run.muls
            );
        }

        // --- 2. The adaptive ladder: widen out of FP8 immediately, and
        // (once the dynamics decay into a stall) narrow back for the tail.
        let mut sched = AdaptiveArith::new((spec.adaptive_policy)());
        let _run =
            (spec.run_adaptive)(ScenarioSize::Adaptive, &mut sched, QuantMode::MulOnly, true);
        let rep = sched.report();
        println!(
            "  adaptive {:?}: widen {}  narrow {}  final {}  modeled cost {:.3e} LUT·ops",
            rep.ops_per_rung.iter().map(|(f, _)| f.to_string()).collect::<Vec<_>>(),
            rep.widen_events,
            rep.narrow_events,
            rep.final_format,
            rep.modeled_cost_lut
        );
        for ev in rep.trace.iter().take(4) {
            println!(
                "    step {:>5}: {} -> {} ({})",
                ev.step,
                ev.from,
                ev.to,
                if ev.widened { "widen + retry" } else { "narrow" }
            );
        }
        println!();
    }

    // --- 3. Burgers: the nonlinearity multiplies the state by itself.
    let p = advection1d::AdvectionParams {
        n: 128,
        steps: 120,
        ..advection1d::AdvectionParams::burgers_default()
    };
    let reference = advection1d::run(&p, &mut F64Arith, QuantMode::MulOnly);
    let mut half = FixedArith::new(FpFormat::E5M10);
    let res = advection1d::run(&p, &mut half, QuantMode::MulOnly);
    println!(
        "burgers (u in [20,100], shock forming): E5M10 rel-err {:.3e} over {} u*u muls",
        rel_l2(&res.u, &reference.u),
        res.muls
    );
    println!("\nNext: `cargo test --test scenario_matrix` (the registry contract)");
}
