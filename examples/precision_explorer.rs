//! Precision exploration (the paper's §3, Figs 2 & 3): study the data
//! distribution of a live simulation, profile arbitrary precision
//! configurations over operand ranges, and test the Eq.(1) intuition.
//!
//! ```sh
//! cargo run --release --example precision_explorer
//! ```

use r2f2::analysis::heat_distribution;
use r2f2::pde::heat1d::HeatParams;
use r2f2::report::ascii_plot::histogram;
use r2f2::report::{sig, Table};
use r2f2::sweep::config_profile::{
    best_of, eq1_exponent_bits, profile_range, sixteen_bit_family, PAPER_RANGES,
};

fn main() {
    // --- Fig 2: data distribution during the heat simulation.
    let mut p = HeatParams::default();
    p.n = 257;
    p.dt = 0.25 / (256.0f64 * 256.0);
    p.steps = 2048;
    let rep = heat_distribution(&p, 4);
    println!(
        "Fig 2(a): octave histogram of every multiplication operand/result\n\
         ({} samples; zeros: {})",
        rep.samples, rep.overall.zeros
    );
    println!("{}", histogram("", &rep.overall.bars(), 40));
    let (lo, hi) = rep.overall.nonzero_range().unwrap();
    println!("global range: {:.3e} .. {:.3e}  (globally wide)", lo, hi);

    let mut t = Table::new(vec!["stage", "min |v|", "max |v|", "90% of data within"]);
    for s in &rep.stages {
        t.row(vec![
            format!("{}/4", s.index + 1),
            sig(s.min_abs, 3),
            sig(s.max_abs, 3),
            format!("{} octaves", s.histogram.bulk_octaves(0.9)),
        ]);
    }
    println!("Fig 2(b/c): the range shifts as the simulation proceeds\n{}", t.render());

    // --- Fig 3 / §3.2: profile configurations per operand range.
    println!("Fig 3: average error of 16-bit configurations per operand range");
    let configs = sixteen_bit_family();
    for (lo, hi) in PAPER_RANGES {
        let pts = profile_range(lo, hi, &configs, 1000, 42);
        let best = best_of(&pts);
        let row: Vec<String> =
            pts.iter().map(|p| format!("{}:{}", p.fmt, sig(p.avg_err, 2))).collect();
        println!("  ({lo}, {hi}): {}", row.join("  "));
        println!(
            "    → profiled best {} | Eq.(1) suggests E{} | {}",
            best.fmt,
            eq1_exponent_bits(hi),
            if best.fmt.e_w == eq1_exponent_bits(hi) {
                "agree"
            } else {
                "DISAGREE — the paper's point: intuition is unreliable"
            }
        );
    }
    println!("\nConclusion (§3.2): \"represent data using low bitwidth but flexible\n\
              precision\" + \"adjust precision at runtime\" — which is what R2F2 does.");
}
