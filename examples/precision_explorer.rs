//! Precision exploration (the paper's §3, Figs 2 & 3): study the data
//! distribution of a live simulation, profile arbitrary precision
//! configurations over operand ranges, and test the Eq.(1) intuition.
//!
//! ```sh
//! cargo run --release --example precision_explorer
//! ```

use r2f2::analysis::heat_distribution;
use r2f2::pde::adaptive::fixed_cost_lut;
use r2f2::pde::heat1d::{self, HeatParams};
use r2f2::pde::{rmse, AdaptiveArith, AdaptivePolicy, F64Arith, FixedArith, QuantMode};
use r2f2::report::ascii_plot::histogram;
use r2f2::report::{sig, Table};
use r2f2::softfloat::FpFormat;
use r2f2::sweep::config_profile::{
    best_of, eq1_exponent_bits, profile_range, sixteen_bit_family, PAPER_RANGES,
};

fn main() {
    // --- Fig 2: data distribution during the heat simulation.
    let mut p = HeatParams::default();
    p.n = 257;
    p.dt = 0.25 / (256.0f64 * 256.0);
    p.steps = 2048;
    let rep = heat_distribution(&p, 4);
    println!(
        "Fig 2(a): octave histogram of every multiplication operand/result\n\
         ({} samples; zeros: {})",
        rep.samples, rep.overall.zeros
    );
    println!("{}", histogram("", &rep.overall.bars(), 40));
    let (lo, hi) = rep.overall.nonzero_range().unwrap();
    println!("global range: {:.3e} .. {:.3e}  (globally wide)", lo, hi);

    let mut t = Table::new(vec!["stage", "min |v|", "max |v|", "90% of data within"]);
    for s in &rep.stages {
        t.row(vec![
            format!("{}/4", s.index + 1),
            sig(s.min_abs, 3),
            sig(s.max_abs, 3),
            format!("{} octaves", s.histogram.bulk_octaves(0.9)),
        ]);
    }
    println!("Fig 2(b/c): the range shifts as the simulation proceeds\n{}", t.render());

    // --- Fig 3 / §3.2: profile configurations per operand range.
    println!("Fig 3: average error of 16-bit configurations per operand range");
    let configs = sixteen_bit_family();
    for (lo, hi) in PAPER_RANGES {
        let pts = profile_range(lo, hi, &configs, 1000, 42);
        let best = best_of(&pts);
        let row: Vec<String> =
            pts.iter().map(|p| format!("{}:{}", p.fmt, sig(p.avg_err, 2))).collect();
        println!("  ({lo}, {hi}): {}", row.join("  "));
        println!(
            "    → profiled best {} | Eq.(1) suggests E{} | {}",
            best.fmt,
            eq1_exponent_bits(hi),
            if best.fmt.e_w == eq1_exponent_bits(hi) {
                "agree"
            } else {
                "DISAGREE — the paper's point: intuition is unreliable"
            }
        );
    }
    println!("\nConclusion (§3.2): \"represent data using low bitwidth but flexible\n\
              precision\" + \"adjust precision at runtime\" — which is what R2F2 does.");

    // --- §10: the same idea at solver granularity — the adaptive
    // precision scheduler's live schedule trace on a decaying heat run.
    println!("\nAdaptive precision schedule (DESIGN.md §10): E4M3 → E5M10 ladder");
    let hp =
        HeatParams { n: 33, dt: 0.25 / (32.0f64 * 32.0), steps: 2600, ..HeatParams::default() };
    let mut policy = AdaptivePolicy::heat_default();
    policy.epoch_len = 50;
    let mut sched = AdaptiveArith::new(policy);
    let adaptive = heat1d::run_adaptive(&hp, &mut sched, QuantMode::MulOnly);
    let rep = sched.report();

    let mut t = Table::new(vec!["epoch", "step", "switch", "why"]);
    for ev in &rep.trace {
        t.row(vec![
            ev.epoch.to_string(),
            ev.step.to_string(),
            format!("{} → {}", ev.from, ev.to),
            if ev.widened { "overflow pressure (epoch retried)".into() } else {
                "clean streak + stalled dynamics".to_string()
            },
        ]);
    }
    println!("{}", t.render());

    let reference = heat1d::run(&hp, &mut F64Arith, QuantMode::MulOnly);
    let mut wide = FixedArith::new(FpFormat::E5M10);
    let fixed = heat1d::run(&hp, &mut wide, QuantMode::MulOnly);
    let mut ops = Table::new(vec!["format", "muls charged", "modeled LUT·ops"]);
    for (fmt, n) in &rep.ops_per_rung {
        ops.row(vec![fmt.to_string(), n.to_string(), sig(fixed_cost_lut(*fmt, *n), 4)]);
    }
    println!("{}", ops.render());
    println!(
        "adaptive RMSE {} vs all-E5M10 {} (vs f64) | modeled cost {} vs all-E5M10 {} \
         ({}% saved)",
        sig(rmse(&adaptive.u, &reference.u), 3),
        sig(rmse(&fixed.u, &reference.u), 3),
        sig(rep.modeled_cost_lut, 4),
        sig(fixed_cost_lut(FpFormat::E5M10, fixed.muls), 4),
        sig(
            100.0 * (1.0 - rep.modeled_cost_lut / fixed_cost_lut(FpFormat::E5M10, fixed.muls)),
            3
        ),
    );
}
