//! Heat-equation case study (Figs 1 & 7): run the 1D heat equation at the
//! paper's scale (~1.5 M multiplications) under f64 / f32 / E5M10 / R2F2
//! and compare the final temperature profiles.
//!
//! ```sh
//! cargo run --release --example heat_equation [-- sin|exp]
//! ```

use r2f2::pde::heat1d::{run, HeatParams};
use r2f2::pde::init::HeatInit;
use r2f2::pde::{rel_l2, F32Arith, F64Arith, FixedArith, QuantMode, R2f2Arith};
use r2f2::r2f2core::R2f2Config;
use r2f2::report::ascii_plot::line_plot;
use r2f2::report::Table;
use r2f2::softfloat::FpFormat;

fn main() {
    let init = match std::env::args().nth(1).as_deref() {
        Some("exp") => HeatInit::exp_default(),
        _ => HeatInit::sin_default(),
    };
    let params = HeatParams { init, ..HeatParams::default() };
    println!(
        "1D heat equation: n={}, steps={}, r={}, init={}  (~{} muls)",
        params.n,
        params.steps,
        params.r(),
        params.init.name(),
        params.expected_muls()
    );

    let truth = run(&params, &mut F64Arith, QuantMode::MulOnly);

    let mut table = Table::new(vec!["backend", "mode", "rel-err vs f64", "notes"]);
    let mut series: Vec<(String, Vec<f64>)> = vec![("f64".into(), sample(&truth.u))];

    // f32 — the paper's "32-bit" reference that R2F2 must match.
    let f32_run = run(&params, &mut F32Arith, QuantMode::MulOnly);
    table.row(vec![
        "f32".to_string(),
        "mul-only".into(),
        format!("{:.2e}", rel_l2(&f32_run.u, &truth.u)),
        "reference".into(),
    ]);

    // Standard half, honestly deployed (state + arithmetic) — Fig 1(b).
    let mut half = FixedArith::new(FpFormat::E5M10);
    let half_run = run(&params, &mut half, QuantMode::Full);
    let ev = half_run.range_events.unwrap();
    table.row(vec![
        "E5M10".to_string(),
        "full".into(),
        format!("{:.2e}", rel_l2(&half_run.u, &truth.u)),
        format!("WRONG — {} overflows, {} underflows", ev.overflows, ev.underflows),
    ]);
    series.push(("E5M10-full".into(), sample(&half_run.u)));

    // R2F2 16- and 15-bit — Fig 7(a)/(b).
    for cfg in [R2f2Config::C16_393, R2f2Config::C15_383] {
        let mut unit = R2f2Arith::new(cfg);
        let res = run(&params, &mut unit, QuantMode::MulOnly);
        let st = res.r2f2_stats.unwrap();
        table.row(vec![
            format!("R2F2 {cfg}"),
            "mul-only".into(),
            format!("{:.2e}", rel_l2(&res.u, &truth.u)),
            format!(
                "{} widen / {} narrow in {} muls (paper: 5 / 23)",
                st.overflow_adjustments, st.redundancy_adjustments, st.muls
            ),
        ]);
        if cfg == R2f2Config::C16_393 {
            series.push((format!("R2F2{cfg}"), sample(&res.u)));
        }
    }

    println!("\n{}", table.render());
    let refs: Vec<(&str, &[f64])> = series.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect();
    println!("{}", line_plot("final temperature profiles (Figs 1/7)", &refs, 72, 16));
    println!("R2F2 rides the f64 curve; the fully-half run visibly distorts.");
}

fn sample(u: &[f64]) -> Vec<f64> {
    u.iter().step_by(u.len().div_ceil(72)).copied().collect()
}
