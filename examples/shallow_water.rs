//! Shallow-water case study (Fig 8): 2D Lax–Wendroff with the paper's
//! substituted sub-equation (`Ux_mx = q1²/q3 + 0.5g·q3²`) running in
//! f64 / E5M10 / R2F2-16 — ~30 K quantized multiplications.
//!
//! ```sh
//! cargo run --release --example shallow_water
//! ```

use r2f2::pde::swe2d::{run, QuantScope, SweParams};
use r2f2::pde::{rel_l2, F64Arith, FixedArith, R2f2Arith};
use r2f2::r2f2core::R2f2Config;
use r2f2::report::ascii_plot::surface;
use r2f2::report::Table;
use r2f2::softfloat::FpFormat;

fn main() {
    let mut params = SweParams::default();
    params.steps = 40; // two wave reflections across the basin
    params.snapshot_every = 20;
    println!(
        "2D shallow water: {}×{} cells of {} m, depth {} m, {} steps ({} quantized muls)",
        params.n,
        params.n,
        params.dx,
        params.init.base_depth,
        params.steps,
        6 * params.n * params.n * params.steps,
    );
    println!(
        "substituted flux magnitude 0.5·g·h² ≈ {:.3e}  > E5M10 max {:.0} → half saturates\n",
        0.5 * params.g * params.init.base_depth * params.init.base_depth,
        FpFormat::E5M10.max_value()
    );

    let truth = run(&params, &mut F64Arith, QuantScope::UxFluxOnly);

    let mut half = FixedArith::new(FpFormat::E5M10);
    let half_run = run(&params, &mut half, QuantScope::UxFluxOnly);
    let he = half_run.range_events.unwrap();

    let mut unit = R2f2Arith::new(R2f2Config::C16_384);
    let r2f2_run = run(&params, &mut unit, QuantScope::UxFluxOnly);
    let st = r2f2_run.r2f2_stats.unwrap();

    let mut t = Table::new(vec!["backend", "rel-err vs f64", "mass drift", "events"]);
    t.row(vec![
        "f64".to_string(),
        "0".into(),
        format!("{:.1e}", truth.mass_drift),
        "-".into(),
    ]);
    t.row(vec![
        "E5M10".to_string(),
        format!("{:.2e}", rel_l2(&half_run.h, &truth.h)),
        format!("{:.1e}", half_run.mass_drift),
        format!("{} overflows (saturated flux!)", he.overflows),
    ]);
    t.row(vec![
        "R2F2 <3,8,4>".to_string(),
        format!("{:.2e}", rel_l2(&r2f2_run.h, &truth.h)),
        format!("{:.1e}", r2f2_run.mass_drift),
        format!(
            "{} widen / {} narrow in {} muls (paper: 7 / 15)",
            st.overflow_adjustments, st.redundancy_adjustments, st.muls
        ),
    ]);
    println!("{}", t.render());

    // Wave-height deviation fields (subtract the base depth for contrast).
    let dev =
        |h: &[f64]| h.iter().map(|&x| x - params.init.base_depth).collect::<Vec<f64>>();
    println!("{}", surface("f64 waves (Fig 8a)", &dev(&truth.h), params.n));
    println!("{}", surface("R2F2-16 waves (Fig 8b) — same pattern", &dev(&r2f2_run.h), params.n));
    println!(
        "{}",
        surface("E5M10 waves (Fig 8c) — corrupted pattern", &dev(&half_run.h), params.n)
    );
}
