//! End-to-end three-layer driver — the full system on a real workload.
//!
//! Layer 1 (Pallas R2F2 kernels) and Layer 2 (JAX heat/SWE models) were
//! AOT-lowered by `make artifacts`; this binary is Layer 3: it loads the
//! HLO artifacts, compiles them on the PJRT CPU client, and drives both
//! case studies through thousands of steps with **no python anywhere on the
//! path** — then verifies the paper's headline claim on the compiled stack:
//! R2F2-16 matches the 32-bit trajectory where standard half fails.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use r2f2::metrics::Registry;
use r2f2::report::ascii_plot::line_plot;
use r2f2::report::Table;
use r2f2::runtime::{HeatRunner, Runtime, SweRunner};

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| (x as f64 - y as f64).powi(2)).sum();
    let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum();
    (num / den).sqrt()
}

fn main() {
    // A missing PJRT runtime / artifact directory is an environment gap,
    // not a failure: skip politely (exit 0) so smoke harnesses can run
    // every example unconditionally. Anything that goes wrong *after* the
    // runtime probe succeeded is a genuine regression and exits nonzero
    // (assertion failures still panic).
    let mut rt = match Runtime::from_default_dir() {
        Ok(rt) => rt,
        Err(e) => {
            println!("e2e pipeline skipped: {e}");
            return;
        }
    };
    if let Err(e) = pipeline(&mut rt) {
        eprintln!("e2e pipeline failed: {e}");
        std::process::exit(1);
    }
}

fn pipeline(rt: &mut Runtime) -> r2f2::runtime::Result<()> {
    let metrics = Registry::new();
    println!("PJRT platform: {} | artifacts: {}", rt.platform(), rt.manifest.dir.display());

    // ---------------- Heat equation through the compiled stack ----------
    let n = rt.manifest.heat_n;
    let steps = 1000; // ~1.5 M emulated multiplications at n=512
    let u0: Vec<f32> = (0..n)
        .map(|i| 500.0 * (2.0 * std::f32::consts::PI * i as f32 / (n - 1) as f32).sin())
        .collect();

    let mut table = Table::new(vec!["variant", "steps/s", "rel-err vs f32", "widen", "narrow"]);
    let f32_runner = HeatRunner::new(rt, "heat_step_f32", metrics.clone())?;
    let reference = f32_runner.run(&u0, 0.25, steps, 0)?;
    table.row(vec![
        "heat_step_f32".to_string(),
        format!("{:.0}", steps as f64 / reference.elapsed.as_secs_f64()),
        "reference".into(),
        "-".into(),
        "-".into(),
    ]);

    let mut final_fields = vec![("f32".to_string(), reference.u.clone())];
    for variant in ["heat_step_r2f2", "heat_step_e5m10"] {
        let runner = HeatRunner::new(rt, variant, metrics.clone())?;
        let out = runner.run(&u0, 0.25, steps, 2)?;
        table.row(vec![
            variant.to_string(),
            format!("{:.0}", steps as f64 / out.elapsed.as_secs_f64()),
            format!("{:.2e}", rel_l2(&out.u, &reference.u)),
            out.widen.to_string(),
            out.narrow.to_string(),
        ]);
        final_fields.push((variant.to_string(), out.u));
    }
    println!("\nHeat equation ({n} nodes × {steps} steps):\n{}", table.render());

    let sampled: Vec<(String, Vec<f64>)> = final_fields
        .iter()
        .map(|(name, u)| {
            (name.clone(), u.iter().step_by(n / 72).map(|&x| x as f64).collect())
        })
        .collect();
    let refs: Vec<(&str, &[f64])> =
        sampled.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect();
    println!("{}", line_plot("PJRT heat profiles", &refs, 72, 14));

    // ---------------- Shallow water through the compiled stack ----------
    let sn = rt.manifest.swe_n;
    let side = sn + 2;
    let mut h0 = vec![150.0f32; side * side];
    let dx = 2000.0f32;
    let sidelen = sn as f32 * dx;
    let w = 0.15 * sidelen;
    for j in 0..sn {
        for i in 0..sn {
            let x = (i as f32 + 0.5) / sn as f32 * sidelen - 0.5 * sidelen;
            let y = (j as f32 + 0.5) / sn as f32 * sidelen - 0.5 * sidelen;
            h0[(i + 1) * side + (j + 1)] = 150.0 + 6.0 * (-(x * x + y * y) / (w * w)).exp();
        }
    }
    let swe_steps = 40;
    let swe_f32 = SweRunner::new(rt, "swe_step_f32", metrics.clone())?;
    let ref_swe = swe_f32.run(&h0, swe_steps, 0)?;
    let swe_r2f2 = SweRunner::new(rt, "swe_step_r2f2", metrics.clone())?;
    let out_swe = swe_r2f2.run(&h0, swe_steps, 2)?;
    println!(
        "Shallow water ({sn}×{sn} × {swe_steps} steps): R2F2 rel-err vs f32 = {:.2e}, \
         widen={}, narrow={}, {:.0} steps/s",
        rel_l2(&out_swe.h, &ref_swe.h),
        out_swe.widen,
        out_swe.narrow,
        swe_steps as f64 / out_swe.elapsed.as_secs_f64()
    );

    // ---------------- Headline verdict --------------------------------
    // The §3.1 failure regime: "multiplications whose operands are smaller
    // than 0.0001" — the late stage of a long simulation (Fig 2b's final
    // quarter). E5M10 flushes the stencil products to zero and freezes the
    // field; R2F2's adjustment unit widens the exponent and keeps tracking.
    let tiny: Vec<f32> = (0..n)
        .map(|i| 5e-4 * (2.0 * std::f32::consts::PI * i as f32 / (n - 1) as f32).sin())
        .collect();
    let late_ref = f32_runner.run(&tiny, 0.25, steps, 0)?;
    let late_r2f2 = HeatRunner::new(rt, "heat_step_r2f2", metrics.clone())?
        .run(&tiny, 0.25, steps, 2)?;
    let late_half = HeatRunner::new(rt, "heat_step_e5m10", metrics.clone())?
        .run(&tiny, 0.25, steps, 0)?;
    let err_r2f2 = rel_l2(&late_r2f2.u, &late_ref.u);
    let err_half = rel_l2(&late_half.u, &late_ref.u);
    println!("\n== HEADLINE (paper §5.3, on the compiled three-layer stack) ==");
    println!("  late-stage field (|u| ≤ 5e-4, the §3.1 regime), {steps} steps:");
    println!("  R2F2-16 vs f32 error: {err_r2f2:.2e}  (\"same simulation results\")");
    println!(
        "  E5M10  vs f32 error: {err_half:.2e}  ({:.0}× worse — products underflow, field freezes)",
        err_half / err_r2f2
    );
    assert!(err_r2f2 < 5e-3, "R2F2 must track f32: {err_r2f2}");
    assert!(err_half > 10.0 * err_r2f2, "E5M10 must fail: {err_half} vs {err_r2f2}");
    println!("\n{}", metrics.render());
    Ok(())
}
