//! Quickstart: the R2F2 multiplier in five minutes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the core API: quantizing to arbitrary formats, multiplying through
//! the runtime-reconfigurable unit, and watching the adjustment unit react
//! to the data.

use r2f2::r2f2core::{AdjustEvent, R2f2Config, R2f2Multiplier};
use r2f2::softfloat::{mul_f, quantize, FpFormat};

fn main() {
    // --- 1. Arbitrary-precision formats (the paper's exploration library).
    let half = FpFormat::E5M10; // standard half
    let e6m9 = FpFormat::new(6, 9); // one more exponent bit, one less mantissa
    println!("E5M10 range: [{:.3e}, {:.3e}]", half.min_normal(), half.max_value());
    println!("E6M9  range: [{:.3e}, {:.3e}]", e6m9.min_normal(), e6m9.max_value());
    println!("quantize(3.14159, E5M10) = {}", quantize(3.14159, half));

    // --- 2. Fixed-format multiplication fails outside its range.
    let (v, flags) = mul_f(300.0, 300.0, half);
    println!("\n300 × 300 in E5M10 = {v} (overflow: {})  ← the Fig. 6(a) failure", flags.overflow());

    // --- 3. The R2F2 multiplier widens its exponent and retries.
    let mut unit = R2f2Multiplier::new(R2f2Config::C16_393); // 16-bit <3,9,3>
    let (v, event) = unit.mul_traced(300.0, 300.0);
    println!("300 × 300 in R2F2 <3,9,3> = {v} ({event:?})");
    println!("unit now at split k={} (format {})", unit.split(), unit.config().format(unit.split()));

    // --- 4. And narrows back when the data clusters near 1.0.
    let mut narrowed = false;
    for i in 0..40 {
        let (_, ev) = unit.mul_traced(1.05, 0.97);
        if ev == AdjustEvent::Narrowed {
            println!("after {} small multiplications: narrowed to k={}", i + 1, unit.split());
            narrowed = true;
            break;
        }
    }
    assert!(narrowed);

    // --- 5. Accuracy accounting.
    let st = unit.stats();
    println!(
        "\nstats: {} muls, {} widen retries, {} narrowings, {} unresolved",
        st.muls, st.overflow_adjustments, st.redundancy_adjustments, st.unresolved_range_events
    );
    println!("\nNext: `cargo run --release --example heat_equation` (Figs 1 & 7)");
}
