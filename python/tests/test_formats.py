"""Vectorized jnp emulation (compile.formats) vs the independent scalar
oracle (compile.kernels.ref) — hypothesis-driven, bit-exact."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import formats
from compile.kernels import ref

FORMATS = [(5, 10), (5, 9), (5, 8), (6, 9), (4, 11), (3, 12), (8, 7), (2, 13)]
CONFIGS = [formats.C16_393, formats.C16_384, formats.C15_383, formats.C14_373]


def bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


finite_f32 = st.floats(
    min_value=np.float32(-1e30),
    max_value=np.float32(1e30),
    allow_nan=False,
    allow_infinity=False,
    width=32,
)


@settings(max_examples=300, deadline=None)
@given(finite_f32, st.sampled_from(FORMATS))
def test_quantize_matches_oracle(x, fmt):
    e_w, m_w = fmt
    got = formats.quantize(jnp.asarray([x], jnp.float32), e_w, m_w)
    want = ref.quantize_ref(float(np.float32(x)), e_w, m_w)
    assert bits(got)[0] == bits([want])[0], (x, fmt, float(got[0]), want)


@settings(max_examples=300, deadline=None)
@given(finite_f32, finite_f32, st.sampled_from(FORMATS))
def test_fixed_mul_matches_oracle(a, b, fmt):
    e_w, m_w = fmt
    got, _, _ = formats.fixed_mul(
        jnp.asarray([a], jnp.float32), jnp.asarray([b], jnp.float32), e_w, m_w
    )
    want = ref.fixed_mul_ref(float(np.float32(a)), float(np.float32(b)), e_w, m_w)
    assert bits(got)[0] == bits([want])[0], (a, b, fmt)


@settings(max_examples=200, deadline=None)
@given(
    finite_f32,
    finite_f32,
    st.sampled_from(CONFIGS),
    st.integers(min_value=0, max_value=3),
)
def test_adaptive_mul_matches_oracle_unit(a, b, cfg, k0):
    k0 = min(k0, cfg.fx)
    res, k2, s2, w, nr, un = formats.r2f2_adaptive_mul(
        jnp.asarray([a], jnp.float32),
        jnp.asarray([b], jnp.float32),
        jnp.asarray([k0], jnp.int32),
        jnp.asarray([0], jnp.int32),
        cfg,
    )
    unit = ref.R2f2UnitRef(cfg.eb, cfg.mb, cfg.fx, k=k0)
    want = unit.mul(float(np.float32(a)), float(np.float32(b)))
    assert bits(res)[0] == bits([want])[0], (a, b, cfg, k0)
    assert int(k2[0]) == unit.k
    assert int(w[0]) == unit.widen_count
    assert int(nr[0]) == unit.narrow_count
    assert int(un[0]) == unit.unresolved


def test_streak_state_threads_across_calls():
    """Narrowing needs STREAK_THRESHOLD consecutive redundant muls carried
    through the state arrays."""
    cfg = formats.C16_393
    a = jnp.asarray([1.1], jnp.float32)
    b = jnp.asarray([0.9], jnp.float32)
    k = jnp.asarray([2], jnp.int32)
    s = jnp.asarray([0], jnp.int32)
    narrowed_at = None
    for i in range(formats.STREAK_THRESHOLD + 5):
        _, k, s, _, nr, _ = formats.r2f2_adaptive_mul(a, b, k, s, cfg)
        if int(nr[0]) and narrowed_at is None:
            narrowed_at = i
    assert narrowed_at == formats.STREAK_THRESHOLD - 1
    assert int(k[0]) == 1


def test_special_values():
    e_w, m_w = 5, 10
    x = jnp.asarray([np.inf, -np.inf, np.nan, 0.0, -0.0, 65504.0, 65520.0], jnp.float32)
    q = np.asarray(formats.quantize(x, e_w, m_w))
    assert q[0] == 65504.0 and q[1] == -65504.0  # inf saturates
    assert q[2] == 0.0  # nan → +0
    assert bits(q[3:5]).tolist() == bits([0.0, -0.0]).tolist()
    assert q[5] == 65504.0
    assert q[6] == 65504.0  # rounds to 2^16 → saturates


def test_truncation_bits_match_rust_table():
    cfg = formats.C16_393
    assert [formats.trunc_bits(cfg, k) for k in range(4)] == [3, 1, 0, 0]


@settings(max_examples=100, deadline=None)
@given(st.lists(finite_f32, min_size=4, max_size=64), st.sampled_from(CONFIGS))
def test_vectorized_equals_per_element(vals, cfg):
    """Vectorization must not couple lanes."""
    n = len(vals) // 2 * 2
    if n == 0:
        return
    a = jnp.asarray(vals[: n // 2], jnp.float32)
    b = jnp.asarray(vals[n // 2 : n], jnp.float32)
    k = jnp.full((n // 2,), 2, jnp.int32)
    s = jnp.zeros((n // 2,), jnp.int32)
    batch = formats.r2f2_adaptive_mul(a, b, k, s, cfg)
    for i in range(n // 2):
        single = formats.r2f2_adaptive_mul(a[i : i + 1], b[i : i + 1], k[i : i + 1], s[i : i + 1], cfg)
        for bx, sx in zip(batch, single):
            assert bits(bx[i : i + 1])[0] == bits(sx)[0] if bx.dtype == jnp.float32 else int(bx[i]) == int(sx[0])
