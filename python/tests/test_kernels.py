"""Pallas kernels (interpret=True) vs the pure-jnp math and the scalar
oracle — the Layer-1 correctness gate."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import formats
from compile.kernels import quantize as qk
from compile.kernels import r2f2 as rk
from compile.kernels import ref, stencil


def bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


def log_uniform(rng, lo, hi, n):
    return np.exp(rng.uniform(np.log(lo), np.log(hi), n)).astype(np.float32)


def test_quantize_kernel_matches_jnp_and_oracle():
    rng = np.random.default_rng(0)
    x = log_uniform(rng, 1e-8, 1e8, 1024) * rng.choice([-1.0, 1.0], 1024).astype(np.float32)
    got = qk.quantize_pallas(jnp.asarray(x), 5, 10)
    want = formats.quantize(jnp.asarray(x), 5, 10)
    assert np.array_equal(bits(got), bits(want))
    for i in range(0, 1024, 97):
        assert bits(got)[i] == bits([ref.quantize_ref(float(x[i]), 5, 10)])[0]


def test_fixed_mul_kernel_matches_jnp():
    rng = np.random.default_rng(1)
    a = log_uniform(rng, 1e-6, 1e6, 512)
    b = log_uniform(rng, 1e-6, 1e6, 512)
    got = qk.fixed_mul_pallas(jnp.asarray(a), jnp.asarray(b), 5, 10)
    want, _, _ = formats.fixed_mul(jnp.asarray(a), jnp.asarray(b), 5, 10)
    assert np.array_equal(bits(got), bits(want))


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=3), st.integers(min_value=0, max_value=2**32 - 1))
def test_fixed_split_kernel_matches_jnp(k, seed):
    cfg = formats.C16_393
    rng = np.random.default_rng(seed)
    a = log_uniform(rng, 1e-4, 1e4, 256)
    b = log_uniform(rng, 1e-4, 1e4, 256)
    got = rk.r2f2_mul_fixed_split_pallas(jnp.asarray(a), jnp.asarray(b), cfg, k)
    want, _, _ = formats.r2f2_mul_at_split(jnp.asarray(a), jnp.asarray(b), cfg, k)
    assert np.array_equal(bits(got), bits(want))


def test_adaptive_kernel_matches_jnp_multi_block():
    """Grid > 1: block decomposition must not change any lane."""
    cfg = formats.C16_393
    rng = np.random.default_rng(3)
    n = 1024  # 4 blocks of 256
    a = log_uniform(rng, 1e-5, 1e5, n)
    b = log_uniform(rng, 1e-5, 1e5, n)
    k = rng.integers(0, cfg.fx + 1, n).astype(np.int32)
    s = rng.integers(0, 31, n).astype(np.int32)
    got = rk.r2f2_mul_pallas(jnp.asarray(a), jnp.asarray(b), jnp.asarray(k), jnp.asarray(s), cfg)
    want = formats.r2f2_adaptive_mul(jnp.asarray(a), jnp.asarray(b), jnp.asarray(k), jnp.asarray(s), cfg)
    for g, w in zip(got, want):
        assert np.array_equal(np.asarray(g), np.asarray(w))


def test_heat_step_kernel_against_scalar_oracle():
    """Full heat step with per-lane adaptive units vs a python loop of
    per-lane R2f2UnitRef instances."""
    cfg = formats.C16_393
    n = 64
    rng = np.random.default_rng(4)
    u = (500.0 * np.sin(2 * np.pi * np.linspace(0, 1, n))).astype(np.float32)
    r = np.float32(0.25)
    k0 = np.full(n, 2, np.int32)
    u1, k1, s1, w, nr = stencil.heat_step_r2f2_pallas(
        jnp.asarray(u), jnp.asarray([r]), jnp.asarray(k0), jnp.zeros(n, jnp.int32), cfg
    )
    # Scalar oracle: lane i has its own unit doing (r·u⁻, 2r·u, r·u⁺).
    two_r = np.float32(2.0) * r
    for i in range(1, n - 1):
        unit = ref.R2f2UnitRef(cfg.eb, cfg.mb, cfg.fx, k=2)
        left = unit.mul(float(r), float(u[i - 1]))
        mid = unit.mul(float(two_r), float(u[i]))
        right = unit.mul(float(r), float(u[i + 1]))
        du = np.float32(np.float32(np.float32(left) - np.float32(mid)) + np.float32(right))
        want = np.float32(u[i] + du)
        assert bits(np.asarray(u1))[i] == bits([want])[0], i
        assert int(k1[i]) == unit.k
    # Boundaries untouched (Dirichlet).
    assert float(u1[0]) == float(u[0]) and float(u1[-1]) == float(u[-1])


def test_heat_step_f32_kernel_is_plain_arithmetic():
    n = 128
    u = np.linspace(-1.0, 1.0, n).astype(np.float32)
    r = np.float32(0.25)
    got = np.asarray(stencil.heat_step_f32_pallas(jnp.asarray(u), jnp.asarray([r])))
    want = u.copy()
    for i in range(1, n - 1):
        du = r * u[i - 1] - (np.float32(2.0) * r) * u[i] + r * u[i + 1]
        want[i] = u[i] + np.float32(du)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_heat_step_fixed_kernel_underflow_behaviour():
    """E5M10 products below 2^-14 flush to zero — the §3.1 failure seed."""
    n = 64
    u = np.full(n, 1e-4, np.float32)  # r·u = 2.5e-5 < 6.1e-5
    r = np.float32(0.25)
    got = np.asarray(stencil.heat_step_fixed_pallas(jnp.asarray(u), jnp.asarray([r]), 5, 10))
    # All three products flush; du = 0; field frozen.
    np.testing.assert_array_equal(got, u)
