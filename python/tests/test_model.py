"""Layer-2 model tests: multi-step trajectories reproduce the paper's
qualitative claims, and the AOT export path stays loadable."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, formats, model


STEP_R2F2 = jax.jit(lambda u, r, k, s: model.heat_step_r2f2(u, r, k, s))
STEP_F32 = jax.jit(model.heat_step_f32)
STEP_E5M10 = jax.jit(lambda u, r: model.heat_step_fixed(u, r, 5, 10))


def test_heat_r2f2_tracks_f32_where_half_fails():
    """Fig. 7(a) in miniature: after enough decay, E5M10 multiplications
    freeze small updates (underflow) while R2F2 follows f32."""
    n = 128
    steps = 600
    r = jnp.asarray([0.25], jnp.float32)
    u0 = model.heat_init_sin(n, amplitude=500.0)

    u_f32 = u0
    for _ in range(steps):
        u_f32 = STEP_F32(u_f32, r)

    u_r2 = u0
    k, s = model.heat_unit_state(n, formats.C16_393)
    for _ in range(steps):
        u_r2, k, s, _, _ = STEP_R2F2(u_r2, r, k, s)

    u_half = u0
    for _ in range(steps):
        u_half = STEP_E5M10(u_half, r)

    ref = np.asarray(u_f32, np.float64)
    err_r2 = np.linalg.norm(np.asarray(u_r2) - ref) / np.linalg.norm(ref)
    err_half = np.linalg.norm(np.asarray(u_half) - ref) / np.linalg.norm(ref)
    assert err_r2 < 5e-3, err_r2
    assert err_r2 <= err_half * 1.05, (err_r2, err_half)


def test_heat_adjustments_are_rare():
    n = 128
    r = jnp.asarray([0.25], jnp.float32)
    u = model.heat_init_sin(n)
    k, s = model.heat_unit_state(n, formats.C16_393)
    widen = 0
    for _ in range(300):
        u, k, s, w, nr = STEP_R2F2(u, r, k, s)
        widen += int(jnp.sum(w))
    total_muls = 300 * 3 * n
    assert widen < total_muls / 100, (widen, total_muls)


def test_swe_mass_conserved_and_stable():
    n = 16
    consts = model.SweConsts(9.8, 20.0, 2000.0)
    step = jax.jit(lambda h, u, v, k, s: model.swe_step(h, u, v, k, s, consts))
    h, u, v = model.swe_drop_init(n)
    k, s = model.swe_unit_state(n, formats.C16_384)
    mass0 = float(jnp.sum(h[1:-1, 1:-1]))
    for _ in range(40):
        h, u, v, k, s, _, _ = step(h, u, v, k, s)
    mass1 = float(jnp.sum(h[1:-1, 1:-1]))
    assert abs(mass1 - mass0) / mass0 < 1e-4
    assert bool(jnp.all(h[1:-1, 1:-1] > 0))


def test_swe_r2f2_beats_half_vs_f32_reference():
    """Fig. 8: E5M10 saturates on 0.5·g·h² ≈ 1.1e5 and corrupts the waves;
    R2F2 widens its exponent and tracks the reference."""
    n = 16
    consts = model.SweConsts(9.8, 20.0, 2000.0)
    steps = 30

    h0, u0, v0 = model.swe_drop_init(n)
    zk = jnp.zeros((1,), jnp.int32)

    step_ref = jax.jit(lambda h, u, v: model.swe_step(h, u, v, zk, zk, consts, cfg=None)[:3])
    step_r2 = jax.jit(lambda h, u, v, k, s: model.swe_step(h, u, v, k, s, consts))
    step_half = jax.jit(
        lambda h, u, v: model.swe_step(h, u, v, zk, zk, consts, cfg=None, fixed=(5, 10))[:3]
    )

    h_ref, u_ref, v_ref = h0, u0, v0
    for _ in range(steps):
        h_ref, u_ref, v_ref = step_ref(h_ref, u_ref, v_ref)

    h_r, u_r, v_r = h0, u0, v0
    k, s = model.swe_unit_state(n, formats.C16_384)
    for _ in range(steps):
        h_r, u_r, v_r, k, s, _, _ = step_r2(h_r, u_r, v_r, k, s)

    h_h, u_h, v_h = h0, u0, v0
    for _ in range(steps):
        h_h, u_h, v_h = step_half(h_h, u_h, v_h)

    ref = np.asarray(h_ref[1:-1, 1:-1], np.float64)
    err_r = np.linalg.norm(np.asarray(h_r[1:-1, 1:-1]) - ref) / np.linalg.norm(ref)
    err_h = np.linalg.norm(np.asarray(h_h[1:-1, 1:-1]) - ref) / np.linalg.norm(ref)
    assert err_r < 1e-3, err_r
    assert err_h > 5 * err_r, (err_h, err_r)


def test_aot_exports_lower_to_parseable_hlo():
    """Every export must lower to non-trivial HLO text containing an ENTRY
    computation (what HloModuleProto::from_text_file parses)."""
    for name, fn, specs, n_out, _ in aot.exports():
        text = aot.to_hlo_text(fn, specs)
        assert "ENTRY" in text, name
        assert "->" in text, name
        assert len(text) > 500, name


def test_manifest_written(tmp_path):
    import subprocess, sys

    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--only", "quantize_e5m10"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["artifacts"][0]["name"] == "quantize_e5m10"
    assert (out / "quantize_e5m10.hlo.txt").exists()


def test_heat_step_jit_has_single_fused_executable():
    """The lowered step must be jit-compilable (no python callbacks)."""
    n = 512
    step = jax.jit(lambda u, r, k, s: model.heat_step_r2f2(u, r, k, s))
    u = model.heat_init_sin(n)
    k, s = model.heat_unit_state(n, formats.C16_393)
    out = step(u, jnp.asarray([0.25], jnp.float32), k, s)
    assert out[0].shape == (n,)
