"""Layer-1 Pallas kernels: the R2F2 multiplier as a TPU-shaped tile kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's bit-serial
FPGA datapath becomes a **vectorized integer-ALU kernel** — one R2F2 unit
per SIMD lane, tiles staged HBM→VMEM by ``BlockSpec``. ``interpret=True``
everywhere: the CPU PJRT plugin cannot run Mosaic custom-calls, and the
lowered HLO is what the rust runtime loads.

All kernels are shape-polymorphic over 1-D arrays padded to the block size.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import formats
from compile.formats import R2f2Config

#: Elementwise tile size — 256 f32 lanes ≈ 1 KiB/operand in VMEM; with the
#: FX+1 candidate evaluations live, the working set stays ≪ 1 MiB.
BLOCK = 256


def _adaptive_kernel(cfg: R2f2Config):
    def kernel(a_ref, b_ref, k_ref, streak_ref, out_ref, k_out_ref, streak_out_ref,
               widen_ref, narrow_ref, unresolved_ref):
        a = a_ref[...]
        b = b_ref[...]
        k = k_ref[...]
        streak = streak_ref[...]
        res, k2, s2, widen, narrow, unresolved = formats.r2f2_adaptive_mul(
            a, b, k, streak, cfg
        )
        out_ref[...] = res
        k_out_ref[...] = k2
        streak_out_ref[...] = s2
        widen_ref[...] = widen
        narrow_ref[...] = narrow
        unresolved_ref[...] = unresolved

    return kernel


def r2f2_mul_pallas(a, b, k, streak, cfg: R2f2Config = formats.C16_393):
    """Adaptive R2F2 multiply over 1-D arrays (length divisible by BLOCK).

    Returns (result, k', streak', widen_delta, narrow_delta, unresolved) —
    all per-lane, matching ``formats.r2f2_adaptive_mul`` bit-for-bit.
    """
    n = a.shape[0]
    assert n % BLOCK == 0, f"length {n} not a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    spec_f = pl.BlockSpec((BLOCK,), lambda i: (i,))
    spec_i = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _adaptive_kernel(cfg),
        grid=grid,
        in_specs=[spec_f, spec_f, spec_i, spec_i],
        out_specs=[spec_f, spec_i, spec_i, spec_i, spec_i, spec_i],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(a, b, k, streak)


def _fixed_split_kernel(cfg: R2f2Config, k: int):
    def kernel(a_ref, b_ref, out_ref):
        a = a_ref[...]
        b = b_ref[...]
        res, _, _ = formats.r2f2_mul_at_split(a, b, cfg, k)
        out_ref[...] = res

    return kernel


def r2f2_mul_fixed_split_pallas(a, b, cfg: R2f2Config, k: int):
    """R2F2 multiply pinned at split ``k`` (no adjustment) — the variant the
    cross-layer bit-exactness artifact uses, since it is stateless."""
    n = a.shape[0]
    assert n % BLOCK == 0
    return pl.pallas_call(
        _fixed_split_kernel(cfg, k),
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))] * 2,
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, b)
