"""Layer-1 Pallas stencil kernel: one explicit heat-equation step with every
multiplication routed through the R2F2 (or fixed-format) emulation, fused
decode→stencil→encode in a single VMEM pass.

The whole field lives in one block: the flagship sizes (≤ 4096 nodes) are a
few KiB — far below VMEM — so no halo exchange is needed, and the HBM↔VMEM
schedule is one load + one store per step, which is the roofline-optimal
shape for a bandwidth-bound stencil.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import formats
from compile.formats import R2f2Config


def _shift_left(u):
    """u[i+1] with the last element replicated (boundary unused)."""
    return jnp.concatenate([u[1:], u[-1:]])


def _shift_right(u):
    """u[i−1] with the first element replicated (boundary unused)."""
    return jnp.concatenate([u[:1], u[:-1]])


def _interior_mask(n):
    idx = jnp.arange(n)
    return (idx > 0) & (idx < n - 1)


def heat_step_r2f2_kernel(cfg: R2f2Config):
    """Kernel body: three sequential adaptive multiplications per lane
    (r·u⁻, 2r·u, r·u⁺) threading the per-lane unit state between them —
    the SIMD analogue of one hardware multiplier seeing the stream."""

    def kernel(u_ref, r_ref, k_ref, streak_ref,
               u_out_ref, k_out_ref, streak_out_ref, widen_ref, narrow_ref):
        u = u_ref[...]
        r = r_ref[0]
        k = k_ref[...]
        streak = streak_ref[...]
        two_r = jnp.float32(2.0) * r

        um = _shift_right(u)
        up = _shift_left(u)
        rb = jnp.broadcast_to(r, u.shape)
        tb = jnp.broadcast_to(two_r, u.shape)

        left, k, streak, w1, n1, _ = formats.r2f2_adaptive_mul(rb, um, k, streak, cfg)
        mid, k, streak, w2, n2, _ = formats.r2f2_adaptive_mul(tb, u, k, streak, cfg)
        right, k, streak, w3, n3, _ = formats.r2f2_adaptive_mul(rb, up, k, streak, cfg)

        du = (left - mid) + right
        unew = u + du
        mask = _interior_mask(u.shape[0])
        u_out_ref[...] = jnp.where(mask, unew, u)
        k_out_ref[...] = k
        streak_out_ref[...] = streak
        widen_ref[...] = w1 + w2 + w3
        narrow_ref[...] = n1 + n2 + n3

    return kernel


def heat_step_r2f2_pallas(u, r, k, streak, cfg: R2f2Config = formats.C16_393):
    """One heat step with R2F2 multiplications.

    Args: ``u`` f32[n], ``r`` f32[1] (diffusion number), per-lane unit state
    ``k``/``streak`` i32[n]. Returns (u', k', streak', widen, narrow).
    """
    n = u.shape[0]
    return pl.pallas_call(
        heat_step_r2f2_kernel(cfg),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(u, r, k, streak)


def heat_step_fixed_pallas(u, r, e_w: int, m_w: int):
    """One heat step with fixed-format multiplications (E5M10 baseline)."""
    n = u.shape[0]

    def kernel(u_ref, r_ref, u_out_ref):
        u_ = u_ref[...]
        r_ = r_ref[0]
        two_r = jnp.float32(2.0) * r_
        rb = jnp.broadcast_to(r_, u_.shape)
        tb = jnp.broadcast_to(two_r, u_.shape)
        left, _, _ = formats.fixed_mul(rb, _shift_right(u_), e_w, m_w)
        mid, _, _ = formats.fixed_mul(tb, u_, e_w, m_w)
        right, _, _ = formats.fixed_mul(rb, _shift_left(u_), e_w, m_w)
        unew = u_ + ((left - mid) + right)
        u_out_ref[...] = jnp.where(_interior_mask(n), unew, u_)

    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,)), pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(u, r)


def heat_step_f32_pallas(u, r):
    """Plain f32 heat step (the 32-bit reference the paper compares to)."""
    n = u.shape[0]

    def kernel(u_ref, r_ref, u_out_ref):
        u_ = u_ref[...]
        r_ = r_ref[0]
        du = r_ * _shift_right(u_) - (jnp.float32(2.0) * r_) * u_ + r_ * _shift_left(u_)
        u_out_ref[...] = jnp.where(_interior_mask(n), u_ + du, u_)

    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,)), pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(u, r)
