"""Independent correctness oracle for the R2F2 numerics.

This is a *separate implementation path* from ``compile.formats``: scalar
numpy/python-int arithmetic following DESIGN.md §3 step by step, written
for clarity rather than speed. The pytest suite checks the vectorized jnp
math and the Pallas kernels against this oracle, and the rust side checks
its scalar implementation against the AOT artifacts — closing the
three-way loop rust ↔ HLO(pallas) ↔ oracle.

Only used by tests; never lowered or shipped.
"""

import math
from typing import NamedTuple, Tuple

import numpy as np

STREAK_THRESHOLD = 32
REDUNDANCY_WINDOW = 2


class Packed(NamedTuple):
    sign: int
    exp: int  # biased; 0 == zero
    frac: int


def _f32_parts(x: float) -> Tuple[int, int, int]:
    bits = int(np.float32(x).view(np.uint32))
    return bits >> 31, (bits >> 23) & 0xFF, bits & 0x7FFFFF


def encode_ref(x: float, e_w: int, m_w: int) -> Tuple[Packed, bool, bool]:
    """f32 value → packed ExMy, returning (packed, overflow, underflow)."""
    sign, e32, f32 = _f32_parts(x)
    if e32 == 255:
        if f32 != 0:  # NaN → +0
            return Packed(0, 0, 0), False, False
        return _max_finite(sign, e_w, m_w), True, False
    if e32 == 0:  # zero or f32 subnormal: flush
        return Packed(sign, 0, 0), False, f32 != 0

    # RNE of the 23-bit fraction to m_w bits.
    shift = 23 - m_w
    kept, lost = f32 >> shift, f32 & ((1 << shift) - 1)
    half = 1 << (shift - 1)
    if lost > half or (lost == half and kept & 1):
        kept += 1
    carry = kept >> m_w
    frac = kept & ((1 << m_w) - 1)

    bias = (1 << (e_w - 1)) - 1
    eb = e32 - 127 + carry + bias
    if eb <= 0:
        return Packed(sign, 0, 0), False, True
    if eb > (1 << e_w) - 2:
        return _max_finite(sign, e_w, m_w), True, False
    return Packed(sign, eb, frac), False, False


def _max_finite(sign: int, e_w: int, m_w: int) -> Packed:
    return Packed(sign, (1 << e_w) - 2, (1 << m_w) - 1)


def decode_ref(p: Packed, e_w: int, m_w: int) -> float:
    if p.exp == 0:
        return -0.0 if p.sign else 0.0
    bias = (1 << (e_w - 1)) - 1
    v = (1.0 + p.frac / (1 << m_w)) * math.ldexp(1.0, p.exp - bias)
    return -v if p.sign else v


def mul_ref(
    a: Packed, b: Packed, e_w: int, m_w: int, trunc: int
) -> Tuple[Packed, bool, bool]:
    """Packed multiply with `trunc` low product bits dropped (exact ints)."""
    sign = a.sign ^ b.sign
    if a.exp == 0 or b.exp == 0:
        return Packed(sign, 0, 0), False, False
    p = ((1 << m_w) | a.frac) * ((1 << m_w) | b.frac)
    if trunc:
        p &= ~((1 << trunc) - 1)
    hi = (p >> (2 * m_w + 1)) & 1
    shift = m_w + hi
    kept, lost = p >> shift, p & ((1 << shift) - 1)
    half = 1 << (shift - 1)
    if lost > half or (lost == half and kept & 1):
        kept += 1
    exp_inc = hi
    if kept >> (m_w + 1):
        kept >>= 1
        exp_inc += 1
    frac = kept & ((1 << m_w) - 1)
    e = a.exp + b.exp - (1 << (e_w - 1)) + 1 + exp_inc
    if e <= 0:
        return Packed(sign, 0, 0), False, True
    if e > (1 << e_w) - 2:
        return _max_finite(sign, e_w, m_w), True, False
    return Packed(sign, e, frac), False, False


def quantize_ref(x: float, e_w: int, m_w: int) -> float:
    p, _, _ = encode_ref(x, e_w, m_w)
    return decode_ref(p, e_w, m_w)


def fixed_mul_ref(a: float, b: float, e_w: int, m_w: int) -> float:
    pa, _, _ = encode_ref(a, e_w, m_w)
    pb, _, _ = encode_ref(b, e_w, m_w)
    pc, _, _ = mul_ref(pa, pb, e_w, m_w, 0)
    return decode_ref(pc, e_w, m_w)


def is_redundant_ref(exp: int, e_w: int, window: int = REDUNDANCY_WINDOW) -> bool:
    if exp == 0:
        return False
    msb = (exp >> (e_w - 1)) & 1
    return all(((exp >> (e_w - 1 - i)) & 1) != msb for i in range(1, window + 1))


def trunc_bits_ref(eb: int, mb: int, fx: int, k: int) -> int:
    f = fx - k
    return max(0, 2 * f - fx)


class R2f2UnitRef:
    """Scalar reference of the stateful multiplier (rust R2f2Multiplier)."""

    def __init__(self, eb: int, mb: int, fx: int, k: int | None = None):
        self.eb, self.mb, self.fx = eb, mb, fx
        self.k = min(max(5 - eb, 0), fx) if k is None else k
        self.streak = 0
        self.widen_count = 0
        self.narrow_count = 0
        self.unresolved = 0

    def _widths(self, k: int) -> Tuple[int, int]:
        return self.eb + k, self.mb + (self.fx - k)

    def mul(self, a: float, b: float) -> float:
        retries = 0
        while True:
            e_w, m_w = self._widths(self.k)
            pa, oa, _ = encode_ref(a, e_w, m_w)
            pb, ob, _ = encode_ref(b, e_w, m_w)
            pc, om, um = mul_ref(
                pa, pb, e_w, m_w, trunc_bits_ref(self.eb, self.mb, self.fx, self.k)
            )
            if oa or ob or om or um:
                self.streak = 0
                if self.k < self.fx:
                    self.k += 1
                    self.widen_count += 1
                    retries += 1
                    continue
                self.unresolved += 1
                return decode_ref(pc, e_w, m_w)
            if retries:
                return decode_ref(pc, e_w, m_w)
            if (
                self.k > 0
                and e_w >= REDUNDANCY_WINDOW + 2
                and is_redundant_ref(pa.exp, e_w)
                and is_redundant_ref(pb.exp, e_w)
                and is_redundant_ref(pc.exp, e_w)
            ):
                self.streak += 1
                if self.streak >= STREAK_THRESHOLD:
                    self.streak = 0
                    self.k -= 1
                    self.narrow_count += 1
            else:
                self.streak = 0
            return decode_ref(pc, e_w, m_w)


def heat_step_ref(u: np.ndarray, r: float, mul) -> np.ndarray:
    """One explicit heat step with multiplications delegated to ``mul``
    (scalar callable) — oracle for the stencil kernels."""
    u = np.asarray(u, np.float64)
    out = u.copy()
    two_r = np.float64(np.float32(2.0 * np.float32(r)))
    for i in range(1, len(u) - 1):
        left = mul(r, u[i - 1])
        mid = mul(two_r, u[i])
        right = mul(r, u[i + 1])
        out[i] = np.float32(u[i] + np.float32(np.float32(left - mid) + right))
    return out
