"""Layer-1 Pallas kernels for fixed-format quantization and multiplication
(the paper's standard-precision baselines: E5M10 etc.)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import formats

BLOCK = 256


def quantize_pallas(x, e_w: int, m_w: int):
    """Round every element to the nearest ``E{e_w}M{m_w}`` value."""
    n = x.shape[0]
    assert n % BLOCK == 0

    def kernel(x_ref, o_ref):
        o_ref[...] = formats.quantize(x_ref[...], e_w, m_w)

    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)


def fixed_mul_pallas(a, b, e_w: int, m_w: int):
    """Elementwise a×b computed entirely in ``E{e_w}M{m_w}`` (single
    rounding), with overflow saturation / underflow flush."""
    n = a.shape[0]
    assert n % BLOCK == 0

    def kernel(a_ref, b_ref, o_ref):
        res, _, _ = formats.fixed_mul(a_ref[...], b_ref[...], e_w, m_w)
        o_ref[...] = res

    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))] * 2,
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, b)
