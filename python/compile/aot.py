"""AOT lowering: JAX/Pallas computations → HLO **text** artifacts + manifest.

Run once at build time (`make artifacts`); the rust runtime
(`rust/src/runtime/`) loads the text with `HloModuleProto::from_text_file`,
compiles on the PJRT CPU client and drives the step loop. HLO text — NOT
`.serialize()` — is the interchange format: jax ≥ 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import formats, model
from compile.kernels import quantize as qk
from compile.kernels import r2f2 as rk

HEAT_N = 512
SWE_N = 16
ELEMWISE_N = 1024


def to_hlo_text(fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def spec_desc(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def exports():
    """Every artifact: (name, fn, input specs, #outputs, note)."""
    cfg = formats.C16_393
    swe_cfg = formats.C16_384
    n_lanes = (SWE_N + 1) * SWE_N
    consts = model.SweConsts(g=9.8, dt=20.0, dx=2000.0)

    return [
        (
            "r2f2_mul_k2",
            lambda a, b: (rk.r2f2_mul_fixed_split_pallas(a, b, cfg, 2),),
            [f32(ELEMWISE_N), f32(ELEMWISE_N)],
            1,
            "stateless <3,9,3> multiply pinned at split k=2 (bit-exactness probe)",
        ),
        (
            "r2f2_mul_k0",
            lambda a, b: (rk.r2f2_mul_fixed_split_pallas(a, b, cfg, 0),),
            [f32(ELEMWISE_N), f32(ELEMWISE_N)],
            1,
            "stateless <3,9,3> multiply pinned at k=0 (max truncation path)",
        ),
        (
            "r2f2_mul_adaptive",
            lambda a, b, k, s: tuple(rk.r2f2_mul_pallas(a, b, k, s, cfg)),
            [f32(ELEMWISE_N), f32(ELEMWISE_N), i32(ELEMWISE_N), i32(ELEMWISE_N)],
            6,
            "adaptive <3,9,3> multiply with per-lane unit state",
        ),
        (
            "quantize_e5m10",
            lambda x: (qk.quantize_pallas(x, 5, 10),),
            [f32(ELEMWISE_N)],
            1,
            "round-to-nearest E5M10 quantizer",
        ),
        (
            "heat_step_r2f2",
            lambda u, r, k, s: tuple(model.heat_step_r2f2(u, r, k, s, cfg)),
            [f32(HEAT_N), f32(1), i32(HEAT_N), i32(HEAT_N)],
            5,
            f"heat step n={HEAT_N}, R2F2 <3,9,3> multiplications",
        ),
        (
            "heat_step_e5m10",
            lambda u, r: (model.heat_step_fixed(u, r, 5, 10),),
            [f32(HEAT_N), f32(1)],
            1,
            f"heat step n={HEAT_N}, fixed E5M10 multiplications",
        ),
        (
            "heat_step_f32",
            lambda u, r: (model.heat_step_f32(u, r),),
            [f32(HEAT_N), f32(1)],
            1,
            f"heat step n={HEAT_N}, plain f32",
        ),
        (
            "swe_step_r2f2",
            lambda h, u, v, k, s: model.swe_step(h, u, v, k, s, consts, cfg=swe_cfg),
            [
                f32(SWE_N + 2, SWE_N + 2),
                f32(SWE_N + 2, SWE_N + 2),
                f32(SWE_N + 2, SWE_N + 2),
                i32(n_lanes),
                i32(n_lanes),
            ],
            7,
            f"SWE Lax-Wendroff step n={SWE_N}, Ux flux through R2F2 <3,8,4>",
        ),
        (
            "swe_step_f32",
            lambda h, u, v: model.swe_step(
                h, u, v, jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
                consts, cfg=None,
            )[:3],
            [
                f32(SWE_N + 2, SWE_N + 2),
                f32(SWE_N + 2, SWE_N + 2),
                f32(SWE_N + 2, SWE_N + 2),
            ],
            3,
            f"SWE Lax-Wendroff step n={SWE_N}, plain f32",
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"heat_n": HEAT_N, "swe_n": SWE_N, "elemwise_n": ELEMWISE_N, "artifacts": []}
    for name, fn, specs, n_out, note in exports():
        if only and name not in only:
            continue
        text = to_hlo_text(fn, specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "inputs": [spec_desc(s) for s in specs],
                "outputs": n_out,
                "note": note,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
