"""Layer-2 JAX models: the PDE step functions that the AOT pipeline lowers
to HLO for the rust runtime. Python never runs at simulation time — rust
owns the step loop and feeds state buffers back into the compiled step.

Heat steps call the Layer-1 Pallas kernels; the shallow-water step uses the
same bit-exact emulation math at the jnp level (its irregular half-step
grids don't tile cleanly, and the flux quantization is 3 elementwise muls —
the fused-stencil story lives in the heat kernel).
"""

from typing import NamedTuple

import jax.numpy as jnp

from compile import formats
from compile.formats import R2f2Config
from compile.kernels import stencil


# --------------------------------------------------------------------------
# Heat equation (Layer-2 wrappers over the Layer-1 kernels)
# --------------------------------------------------------------------------

def heat_init_sin(n: int, amplitude: float = 500.0, cycles: float = 2.0):
    """The paper's Fig. 1(a)/2 initial condition."""
    x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    return (amplitude * jnp.sin(cycles * jnp.pi * x)).astype(jnp.float32)


def heat_init_exp(n: int, rate: float = 10.0):
    """The paper's Fig. 1(c) initial condition."""
    x = jnp.linspace(0.0, 1.0, n, dtype=jnp.float32)
    return (jnp.exp(rate * x) - 1.0).astype(jnp.float32)


def heat_unit_state(n: int, cfg: R2f2Config):
    """Fresh per-lane R2F2 unit state (initial split mimics half's range)."""
    k0 = min(max(5 - cfg.eb, 0), cfg.fx)
    return jnp.full((n,), k0, jnp.int32), jnp.zeros((n,), jnp.int32)


def heat_step_r2f2(u, r, k, streak, cfg: R2f2Config = formats.C16_393):
    """One step, R2F2 multiplications (per-lane adaptive units)."""
    return stencil.heat_step_r2f2_pallas(u, r, k, streak, cfg)


def heat_step_fixed(u, r, e_w: int = 5, m_w: int = 10):
    """One step, fixed-format multiplications (default E5M10)."""
    return stencil.heat_step_fixed_pallas(u, r, e_w, m_w)


def heat_step_f32(u, r):
    """One step, plain f32 — the 32-bit reference."""
    return stencil.heat_step_f32_pallas(u, r)


# --------------------------------------------------------------------------
# Shallow-water equations (Richtmyer two-step Lax–Wendroff, jnp)
# --------------------------------------------------------------------------

class SweConsts(NamedTuple):
    g: float
    dt: float
    dx: float


def swe_drop_init(n: int, base_depth: float = 150.0, amplitude: float = 6.0,
                  width_frac: float = 0.15, dx: float = 2000.0):
    """Padded (n+2)² initial fields matching rust `SweInit::sample`."""
    side = n * dx
    w = width_frac * side
    ij = (jnp.arange(n, dtype=jnp.float32) + 0.5) / n * side
    x = ij[:, None]
    y = ij[None, :]
    d2 = ((x - 0.5 * side) ** 2 + (y - 0.5 * side) ** 2) / (w * w)
    h_int = base_depth + amplitude * jnp.exp(-d2)
    h = jnp.full((n + 2, n + 2), base_depth, jnp.float32)
    # rust fills h[j*n+i] at grid (i+1, j+1): transpose to match.
    h = h.at[1:-1, 1:-1].set(h_int.T.astype(jnp.float32))
    z = jnp.zeros((n + 2, n + 2), jnp.float32)
    return h, z, z


def swe_unit_state(n: int, cfg: R2f2Config):
    """Per-lane unit state for the (n+1)×n flux lanes."""
    k0 = min(max(5 - cfg.eb, 0), cfg.fx)
    lanes = (n + 1) * n
    return jnp.full((lanes,), k0, jnp.int32), jnp.zeros((lanes,), jnp.int32)


def _reflect(h, u, v):
    """Reflective walls, same order as rust `reflect` (rows then columns)."""
    h = h.at[0, :].set(h[1, :]).at[-1, :].set(h[-2, :])
    u = u.at[0, :].set(-u[1, :]).at[-1, :].set(-u[-2, :])
    v = v.at[0, :].set(v[1, :]).at[-1, :].set(v[-2, :])
    h = h.at[:, 0].set(h[:, 1]).at[:, -1].set(h[:, -2])
    u = u.at[:, 0].set(u[:, 1]).at[:, -1].set(u[:, -2])
    v = v.at[:, 0].set(-v[:, 1]).at[:, -1].set(-v[:, -2])
    return h, u, v


def _f2_plain(g2, q1, q3):
    return q1 * q1 / q3 + g2 * (q3 * q3)


def swe_step(h, u, v, k, streak, consts: SweConsts,
             cfg: R2f2Config | None = formats.C16_384,
             fixed: tuple[int, int] | None = None):
    """One Lax–Wendroff step on padded (n+2)² fields.

    The substituted sub-equation (the paper's `Ux_mx = q1²/q3 + 0.5g·q3²`,
    §5.3) — the full-step x-momentum flux from midpoint values — runs
    through the R2F2 units (``cfg``) or a fixed format (``fixed=(e_w,m_w)``)
    or plain f32 (both None). Everything else is f32, like the paper keeps
    the other 23 sub-equations in double.

    Returns (h', u', v', k', streak', widen_total, narrow_total).
    """
    g2 = jnp.float32(0.5 * consts.g)
    ddx = jnp.float32(consts.dt / consts.dx)
    hddx = jnp.float32(0.5) * ddx

    h, u, v = _reflect(h, u, v)

    # x-direction half step: shapes (n+1, n).
    ha, hb = h[1:, 1:-1], h[:-1, 1:-1]
    ua, ub = u[1:, 1:-1], u[:-1, 1:-1]
    va, vb = v[1:, 1:-1], v[:-1, 1:-1]
    hx = 0.5 * (ha + hb) - hddx * (ua - ub)
    ux = 0.5 * (ua + ub) - hddx * (_f2_plain(g2, ua, ha) - _f2_plain(g2, ub, hb))
    vx = 0.5 * (va + vb) - hddx * (ua * va / ha - ub * vb / hb)

    # y-direction half step: shapes (n, n+1).
    ha, hb = h[1:-1, 1:], h[1:-1, :-1]
    ua, ub = u[1:-1, 1:], u[1:-1, :-1]
    va, vb = v[1:-1, 1:], v[1:-1, :-1]
    hy = 0.5 * (ha + hb) - hddx * (va - vb)
    uy = 0.5 * (ua + ub) - hddx * (va * ua / ha - vb * ub / hb)
    vy = 0.5 * (va + vb) - hddx * (_f2_plain(g2, va, ha) - _f2_plain(g2, vb, hb))

    # The quantized sub-equation: F2x over the midpoint (…_mx) values.
    q1 = ux.reshape(-1)
    q3 = hx.reshape(-1)
    widen = jnp.int32(0)
    narrow = jnp.int32(0)
    if cfg is not None:
        g2b = jnp.broadcast_to(g2, q1.shape)
        q1sq, k, streak, w1, n1, _ = formats.r2f2_adaptive_mul(q1, q1, k, streak, cfg)
        q3sq, k, streak, w2, n2, _ = formats.r2f2_adaptive_mul(q3, q3, k, streak, cfg)
        gterm, k, streak, w3, n3, _ = formats.r2f2_adaptive_mul(g2b, q3sq, k, streak, cfg)
        f2x = (q1sq / q3 + gterm).reshape(ux.shape)
        widen = (w1 + w2 + w3).sum()
        narrow = (n1 + n2 + n3).sum()
    elif fixed is not None:
        e_w, m_w = fixed
        q1sq, _, _ = formats.fixed_mul(q1, q1, e_w, m_w)
        q3sq, _, _ = formats.fixed_mul(q3, q3, e_w, m_w)
        g2b = jnp.broadcast_to(g2, q1.shape)
        gterm, _, _ = formats.fixed_mul(g2b, q3sq, e_w, m_w)
        f2x = (q1sq / q3 + gterm).reshape(ux.shape)
    else:
        f2x = _f2_plain(g2, ux, hx)

    # Full step on the interior.
    h_new = h[1:-1, 1:-1] - ddx * (ux[1:, :] - ux[:-1, :]) - ddx * (vy[:, 1:] - vy[:, :-1])
    u_new = (
        u[1:-1, 1:-1]
        - ddx * (f2x[1:, :] - f2x[:-1, :])
        - ddx * (vy[:, 1:] * uy[:, 1:] / hy[:, 1:] - vy[:, :-1] * uy[:, :-1] / hy[:, :-1])
    )
    v_new = (
        v[1:-1, 1:-1]
        - ddx * (ux[1:, :] * vx[1:, :] / hx[1:, :] - ux[:-1, :] * vx[:-1, :] / hx[:-1, :])
        - ddx * (_f2_plain(g2, vy[:, 1:], hy[:, 1:]) - _f2_plain(g2, vy[:, :-1], hy[:, :-1]))
    )

    h = h.at[1:-1, 1:-1].set(h_new)
    u = u.at[1:-1, 1:-1].set(u_new)
    v = v.at[1:-1, 1:-1].set(v_new)
    return h, u, v, k, streak, widen, narrow
