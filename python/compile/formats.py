"""Bit-exact R2F2 / arbitrary-precision float emulation in vectorized jnp.

This is the single source of truth for the Layer-1/Layer-2 numerics, the
Python twin of ``rust/src/softfloat`` + ``rust/src/r2f2core``. Both sides
implement DESIGN.md §3 exactly; the rust integration tests execute the
AOT-lowered HLO of these functions and compare bit-for-bit against the rust
scalar implementation.

Everything operates on f32 carriers with uint32 bit manipulation — no f64
(build-time JAX runs without x64). Supported fraction widths m_w ≤ 14 so
mantissa products fit uint32.

Semantics (same as the rust side):
  * normals only — subnormal inputs and underflowing results flush to zero;
  * no inf/NaN — the top exponent code is reserved; overflow saturates to
    the max finite value and raises a flag;
  * round-to-nearest-even everywhere;
  * R2F2 multiplication truncates the lowest ``t = max(0, 2·(FX−k) − FX)``
    product bits (the paper's flexible-partial-product approximation);
  * the adjustment unit widens (k+1, retry) on result range events or
    operand overflow, and narrows (k−1) after a 32-streak of all-redundant
    multiplications with a 2-bit redundancy window.
"""

from functools import partial
from typing import NamedTuple

import jax.numpy as jnp
from jax import lax


class R2f2Config(NamedTuple):
    """The paper's <EB, MB, FX> configuration."""

    eb: int
    mb: int
    fx: int

    @property
    def total_bits(self) -> int:
        return 1 + self.eb + self.mb + self.fx

    def e_w(self, k: int) -> int:
        return self.eb + k

    def m_w(self, k: int) -> int:
        return self.mb + (self.fx - k)


C16_393 = R2f2Config(3, 9, 3)
C16_384 = R2f2Config(3, 8, 4)
C15_383 = R2f2Config(3, 8, 3)
C14_373 = R2f2Config(3, 7, 3)

#: Narrowing hysteresis (must match rust's R2f2Multiplier default).
STREAK_THRESHOLD = 32
#: Redundancy window bits after the exponent MSB.
REDUNDANCY_WINDOW = 2

_U32 = jnp.uint32


def _u32(x):
    return jnp.asarray(x, dtype=_U32)


def f32_fields(x):
    """Split f32 values into (sign, biased exponent, fraction) uint32s."""
    bits = lax.bitcast_convert_type(jnp.asarray(x, jnp.float32), _U32)
    return bits >> 31, (bits >> 23) & _u32(0xFF), bits & _u32(0x7FFFFF)


def build_f32(sign, e, frac):
    """Assemble f32 from field uint32s (no validation)."""
    bits = (sign << 31) | (e << 23) | frac
    return lax.bitcast_convert_type(bits.astype(_U32), jnp.float32)


def encode(x, e_w: int, m_w: int):
    """Encode f32 → (sign, exp, frac, overflow, underflow) in ``E{e_w}M{m_w}``.

    exp == 0 encodes zero. Overflow saturates to max finite; underflow (and
    f32 subnormal input) flushes to zero. NaN maps to +0, inf saturates —
    matching rust ``softfloat::encode``.
    """
    assert 2 <= e_w <= 8 and 1 <= m_w <= 14
    sign, e32, f32f = f32_fields(x)

    is_zero_or_sub = e32 == 0
    is_nan = (e32 == 255) & (f32f != 0)
    is_inf = (e32 == 255) & (f32f == 0)

    # Round the 23-bit fraction to m_w bits (RNE).
    shift = 23 - m_w
    kept = f32f >> shift
    lost = f32f & _u32((1 << shift) - 1)
    half = _u32(1 << (shift - 1))
    round_up = (lost > half) | ((lost == half) & ((kept & 1) == 1))
    kept = kept + round_up.astype(_U32)
    carry = kept >> m_w  # 0 or 1
    frac = kept & _u32((1 << m_w) - 1)

    bias = (1 << (e_w - 1)) - 1
    max_biased = (1 << e_w) - 2
    # Biased exponent in the target format (signed arithmetic via int32).
    eb = e32.astype(jnp.int32) - 127 + carry.astype(jnp.int32) + bias

    underflow = (eb <= 0) & ~is_zero_or_sub & ~is_nan & ~is_inf
    # f32 subnormals flush silently with an underflow flag like rust.
    sub_underflow = is_zero_or_sub & (f32f != 0)
    overflow = ((eb > max_biased) & ~is_zero_or_sub & ~is_nan) | is_inf

    zero_out = is_zero_or_sub | underflow | is_nan
    exp = jnp.where(zero_out, 0, jnp.where(overflow, max_biased, eb)).astype(_U32)
    frac = jnp.where(zero_out, _u32(0), jnp.where(overflow, _u32((1 << m_w) - 1), frac))
    sign = jnp.where(is_nan, _u32(0), sign)
    return sign, exp, frac, overflow, underflow | sub_underflow


def decode(sign, exp, frac, e_w: int, m_w: int):
    """Decode packed fields back to f32 (exact for every supported format)."""
    bias = (1 << (e_w - 1)) - 1
    is_zero = exp == 0
    e32 = (exp.astype(jnp.int32) - bias + 127).astype(_U32)
    f32f = frac << (23 - m_w)
    out = build_f32(sign, jnp.where(is_zero, _u32(0), e32), jnp.where(is_zero, _u32(0), f32f))
    return out


def mul_fields(sa, ea, fa, sb, eb_, fb, e_w: int, m_w: int, trunc_bits: int):
    """Multiply two packed values with ``trunc_bits`` low product bits dropped.

    Returns (sign, exp, frac, overflow, underflow). Mirrors
    ``r2f2core::mul::mul_packed`` / ``softfloat::mul`` (trunc_bits = 0).
    """
    sign = sa ^ sb
    any_zero = (ea == 0) | (eb_ == 0)

    ia = _u32(1 << m_w) | fa
    ib = _u32(1 << m_w) | fb
    p = ia * ib  # ≤ 2^(2·m_w+2) ≤ 2^30 for m_w ≤ 14
    if trunc_bits > 0:
        p = p & _u32(~((1 << trunc_bits) - 1) & 0xFFFFFFFF)

    hi = (p >> (2 * m_w + 1)) & 1  # product in [2,4)?
    shift = m_w + hi  # dynamic shift (m_w or m_w+1)
    kept = p >> shift
    lost = p & ((_u32(1) << shift) - 1)
    half = _u32(1) << (shift - 1)
    round_up = (lost > half) | ((lost == half) & ((kept & 1) == 1))
    kept = kept + round_up.astype(_U32)
    renorm = kept >> (m_w + 1)  # rounding carried to 2^(m_w+1)?
    kept = jnp.where(renorm == 1, kept >> 1, kept)
    frac = kept & _u32((1 << m_w) - 1)
    exp_inc = hi.astype(jnp.int32) + renorm.astype(jnp.int32)

    # Paper's bias trick: e = ea + eb − 2^(e_w−1) + 1 (+ normalize carries).
    e = ea.astype(jnp.int32) + eb_.astype(jnp.int32) - (1 << (e_w - 1)) + 1 + exp_inc
    max_biased = (1 << e_w) - 2

    underflow = (e <= 0) & ~any_zero
    overflow = (e > max_biased) & ~any_zero
    exp = jnp.where(
        any_zero | underflow, 0, jnp.where(overflow, max_biased, e)
    ).astype(_U32)
    frac = jnp.where(
        any_zero | underflow, _u32(0), jnp.where(overflow, _u32((1 << m_w) - 1), frac)
    )
    return sign, exp, frac, overflow, underflow


def quantize(x, e_w: int, m_w: int):
    """f32 → nearest representable of ``E{e_w}M{m_w}`` → f32."""
    s, e, f, _, _ = encode(x, e_w, m_w)
    return decode(s, e, f, e_w, m_w)


def fixed_mul(a, b, e_w: int, m_w: int):
    """a×b fully in ``E{e_w}M{m_w}``: encode, multiply (one rounding), decode.

    Returns (result, overflow, underflow) — the fixed-format baseline
    (E5M10 = the paper's standard half multiplier).
    """
    sa, ea, fa, oa, ua = encode(a, e_w, m_w)
    sb, eb_, fb, ob, ub = encode(b, e_w, m_w)
    s, e, f, om, um = mul_fields(sa, ea, fa, sb, eb_, fb, e_w, m_w, 0)
    return decode(s, e, f, e_w, m_w), oa | ob | om, ua | ub | um


def _is_redundant(exp, e_w: int, window: int):
    """§4.2 redundancy detector: the `window` bits after the exponent MSB all
    differ from it. Zero is never redundant."""
    msb = (exp >> (e_w - 1)) & 1
    red = exp != 0
    for i in range(1, window + 1):
        bit = (exp >> (e_w - 1 - i)) & 1
        red = red & (bit != msb)
    return red


def trunc_bits(cfg: R2f2Config, k: int) -> int:
    f = cfg.fx - k
    return max(0, 2 * f - cfg.fx)


def r2f2_mul_at_split(a, b, cfg: R2f2Config, k: int):
    """One multiplication attempt at static split ``k``.

    Returns (result_f32, packed fields (s,e,f), widen_event, e_w).
    widen_event = result range event or operand overflow — operand
    underflow is a silent flush (DESIGN.md §3).
    """
    e_w, m_w = cfg.e_w(k), cfg.m_w(k)
    sa, ea, fa, oa, _ = encode(a, e_w, m_w)
    sb, eb_, fb, ob, _ = encode(b, e_w, m_w)
    s, e, f, om, um = mul_fields(sa, ea, fa, sb, eb_, fb, e_w, m_w, trunc_bits(cfg, k))
    widen = oa | ob | om | um
    red = (
        _is_redundant(ea, e_w, REDUNDANCY_WINDOW)
        & _is_redundant(eb_, e_w, REDUNDANCY_WINDOW)
        & _is_redundant(e, e_w, REDUNDANCY_WINDOW)
        if e_w >= REDUNDANCY_WINDOW + 2
        else jnp.zeros_like(s, dtype=bool)
    )
    return decode(s, e, f, e_w, m_w), widen, red


def r2f2_adaptive_mul(a, b, k, streak, cfg: R2f2Config):
    """Vectorized adjustment-unit multiplication: one R2F2 unit **per lane**.

    ``k``/``streak`` are int32 state arrays (one unit per element, the SIMD
    analogue of the paper's per-multiplier state). Implements the cascade
    exactly like rust's ``R2f2Multiplier::mul_traced``: the chosen split is
    the smallest s ≥ k whose attempt raises no widen event (else FX); each
    increment counts one overflow adjustment; narrowing needs a
    ``STREAK_THRESHOLD`` streak of all-redundant multiplications.

    Returns (result, k', streak', widen_count, narrow_count, unresolved).
    Counts are per-lane int32 deltas (sum for the scalar counters).
    """
    k = jnp.asarray(k, jnp.int32)
    streak = jnp.asarray(streak, jnp.int32)

    # Static unroll over all FX+1 candidate splits.
    results, widens, reds = [], [], []
    for s in range(cfg.fx + 1):
        r, w, red = r2f2_mul_at_split(a, b, cfg, s)
        results.append(r)
        widens.append(w)
        reds.append(red)
    res_stack = jnp.stack(results)  # [FX+1, ...]
    widen_stack = jnp.stack(widens)
    red_stack = jnp.stack(reds)

    # chosen = smallest s ≥ k with no widen event; else FX.
    chosen = jnp.full_like(k, cfg.fx)
    for s in range(cfg.fx, -1, -1):
        ok = (jnp.int32(s) >= k) & ~widen_stack[s]
        chosen = jnp.where(ok, jnp.int32(s), chosen)
    chosen = jnp.maximum(chosen, k)

    # Signed-zero-safe select (a one-hot sum would turn −0 into +0).
    res = jnp.take_along_axis(
        jnp.moveaxis(res_stack, 0, -1), chosen[..., None], axis=-1
    )[..., 0]
    widen_at_chosen = jnp.take_along_axis(
        jnp.moveaxis(widen_stack, 0, -1), chosen[..., None], axis=-1
    )[..., 0]
    red_at_chosen = jnp.take_along_axis(
        jnp.moveaxis(red_stack, 0, -1), chosen[..., None], axis=-1
    )[..., 0]

    widen_count = (chosen - k).astype(jnp.int32)
    retried = widen_count > 0
    unresolved = widen_at_chosen.astype(jnp.int32)  # still failing at FX

    # Redundancy streak (only when no retry happened this mul).
    red_ok = red_at_chosen & ~retried & (chosen > 0)
    new_streak = jnp.where(retried | ~red_at_chosen, 0, streak + 1)
    narrow = red_ok & (new_streak >= STREAK_THRESHOLD)
    k_out = jnp.where(narrow, chosen - 1, chosen).astype(jnp.int32)
    new_streak = jnp.where(narrow, 0, new_streak).astype(jnp.int32)

    return res, k_out, new_streak, widen_count, narrow.astype(jnp.int32), unresolved
